//! The assembled dual-interface SSD: NAND + FTL + PCIe + block interface
//! (extent FS) + key-value interface (Dev-LSM namespaces), one device.
//!
//! Everything the host does — Main-LSM file I/O over the block interface,
//! redirected writes over the KV interface, rollback DMA — funnels through
//! this struct, so contention between the two interfaces is physical:
//! they share the same NAND horizons and the same PCIe link, which is the
//! paper's core premise.

use anyhow::Result;

use crate::lsm::entry::{Entry, Key, Seq, ValueDesc};
use crate::sim::{Nanos, MICROS};

use super::block_if::{BlockFs, FileId};
use super::devlsm::{DevLsmConfig, DevSnapshot};
use super::ftl::{Ftl, Region};
use super::kv_if::{KvInterface, NamespaceId};
use super::nand::{NandArray, NandConfig, NandOp};
use super::pcie::{Direction, PcieConfig, PcieLink};

#[derive(Clone, Debug)]
pub struct SsdConfig {
    pub nand: NandConfig,
    pub pcie: PcieConfig,
    pub devlsm: DevLsmConfig,
    /// Fraction of logical pages given to the block interface; the rest
    /// is the KV region (the disaggregation point of Fig 8).
    pub block_fraction: f64,
    /// WAL bytes buffered in the host page cache before an async
    /// writeback is issued (db_bench runs with sync=false).
    pub wal_writeback_bytes: u64,
    /// DMA chunk size for the rollback bulk scan (paper: 512 KB, the
    /// platform's DMA maximum).
    pub dma_chunk_bytes: u64,
}

impl Default for SsdConfig {
    fn default() -> Self {
        Self {
            nand: NandConfig::default(),
            pcie: PcieConfig::default(),
            devlsm: DevLsmConfig::default(),
            block_fraction: 0.8,
            wal_writeback_bytes: 1 << 20,
            dma_chunk_bytes: 512 * 1024,
        }
    }
}

/// One host WAL log's page-cache accounting. Each engine life owns a
/// stream; a sharded store opens one stream per shard (per-shard WAL
/// "directory"), so every shard has its own independent durability cut.
#[derive(Clone, Copy, Debug, Default)]
struct WalStream {
    /// Total bytes ever handed to `wal_append_on` this stream.
    total: u64,
    /// Bytes still in the host page cache (lost on power loss).
    buffered: u64,
}

#[derive(Debug)]
pub struct SsdDevice {
    pub nand: NandArray,
    pub pcie: PcieLink,
    pub ftl: Ftl,
    pub fs: BlockFs,
    pub kv: KvInterface,
    cfg: SsdConfig,
    /// Per-log WAL page-cache accounting; stream 0 is the default log
    /// unsharded engines write.
    wal_streams: Vec<WalStream>,
    /// Power losses survived (each one drops the host page cache and
    /// capacitor-dumps the Dev-LSM memtables).
    pub power_losses: u64,
    /// Device ARM busy ns total (reported alongside host CPU).
    pub device_cpu_ns: Nanos,
}

impl SsdDevice {
    pub fn new(cfg: SsdConfig) -> Self {
        let total_pages = cfg.nand.total_pages;
        let split = (total_pages as f64 * cfg.block_fraction) as u64;
        Self {
            nand: NandArray::new(cfg.nand.clone()),
            pcie: PcieLink::new(cfg.pcie.clone()),
            ftl: Ftl::new(total_pages, split, cfg.nand.page_bytes),
            fs: BlockFs::new(),
            kv: KvInterface::new(cfg.devlsm.clone()),
            cfg,
            wal_streams: vec![WalStream::default()],
            power_losses: 0,
            device_cpu_ns: 0,
        }
    }

    pub fn config(&self) -> &SsdConfig {
        &self.cfg
    }

    // ---------------------------------------------------------------
    // Block interface (Main-LSM side)
    // ---------------------------------------------------------------

    /// Write a whole file (SST) of `bytes`: PCIe-out and NAND programs
    /// overlap (streamed). Returns (file id, completion time).
    pub fn write_file(&mut self, t: Nanos, bytes: u64) -> Result<(FileId, Nanos)> {
        self.write_file_for(0, t, bytes)
    }

    /// [`SsdDevice::write_file`] into an explicit directory (the owning
    /// store's WAL stream id; shards keep separate directories).
    pub fn write_file_for(
        &mut self,
        owner: u32,
        t: Nanos,
        bytes: u64,
    ) -> Result<(FileId, Nanos)> {
        let id = self.fs.create_file_for(&mut self.ftl, owner, bytes)?;
        let pcie_done = self.pcie.transfer(t, bytes, Direction::HostToDevice);
        let nand_done = self.nand.submit(t, bytes, NandOp::Program);
        Ok((id, pcie_done.max(nand_done)))
    }

    /// High-priority file write (memtable flush): fair-shares the NAND
    /// with in-flight compaction streams instead of FIFO-queueing behind
    /// them, and rides the latency-sensitive PCIe path. Keeping flushes
    /// from starving is what keeps flush-based stalls (paper stall type
    /// #1) from swamping every other effect.
    pub fn write_file_priority(&mut self, t: Nanos, bytes: u64) -> Result<(FileId, Nanos)> {
        self.write_file_priority_for(0, t, bytes)
    }

    /// [`SsdDevice::write_file_priority`] into an explicit directory.
    pub fn write_file_priority_for(
        &mut self,
        owner: u32,
        t: Nanos,
        bytes: u64,
    ) -> Result<(FileId, Nanos)> {
        let id = self.fs.create_file_for(&mut self.ftl, owner, bytes)?;
        let pcie_done = self.pcie.transfer_small(t, bytes, Direction::HostToDevice);
        let nand_done = self.nand.submit_priority(t, bytes, NandOp::Program);
        Ok((id, pcie_done.max(nand_done)))
    }

    /// Stream a whole file back to the host (compaction input read).
    pub fn read_file(&mut self, t: Nanos, _id: FileId, bytes: u64) -> Nanos {
        let nand_done = self.nand.submit(t, bytes, NandOp::Read);
        let pcie_done = self.pcie.transfer(t, bytes, Direction::DeviceToHost);
        nand_done.max(pcie_done)
    }

    /// Latency-sensitive small read (one SST block on the get path):
    /// NAND page read then DMA out, sequential.
    pub fn read_block(&mut self, t: Nanos, bytes: u64) -> Nanos {
        let nand_done = self.nand.submit(t, bytes, NandOp::Read);
        self.pcie.transfer(nand_done, bytes, Direction::DeviceToHost)
    }

    pub fn delete_file(&mut self, id: FileId) -> Result<()> {
        self.fs.delete_file(&mut self.ftl, id)
    }

    /// Name already-written bytes as a file without re-charging PCIe or
    /// NAND: a sealed value-log segment's payload was paid for append by
    /// append on its WAL stream, and sealing just gives the extent a
    /// directory entry so recovery and GC can address/delete it.
    pub fn register_file_for(&mut self, owner: u32, bytes: u64) -> Result<FileId> {
        self.fs.create_file_for(&mut self.ftl, owner, bytes)
    }

    /// Make WAL streams `0..n` available (a sharded store opens one log
    /// per shard). Existing streams keep their accounting.
    pub fn wal_ensure_streams(&mut self, n: usize) {
        if self.wal_streams.len() < n {
            self.wal_streams.resize(n, WalStream::default());
        }
    }

    fn wal_stream_mut(&mut self, stream: u32) -> &mut WalStream {
        self.wal_ensure_streams(stream as usize + 1);
        &mut self.wal_streams[stream as usize]
    }

    /// WAL append with page-cache semantics (sync=false): bytes buffer in
    /// host RAM and are written back asynchronously once the threshold
    /// accumulates. Returns immediately-visible time (no device wait).
    pub fn wal_append(&mut self, t: Nanos, bytes: u64) -> Nanos {
        self.wal_append_on(0, t, bytes)
    }

    /// [`SsdDevice::wal_append`] against an explicit WAL log.
    pub fn wal_append_on(&mut self, stream: u32, t: Nanos, bytes: u64) -> Nanos {
        let threshold = self.cfg.wal_writeback_bytes;
        let s = self.wal_stream_mut(stream);
        s.total += bytes;
        s.buffered += bytes;
        if s.buffered >= threshold {
            let flush = s.buffered;
            s.buffered = 0;
            // async writeback: charge the device, do not wait.
            self.pcie.transfer(t, flush, Direction::HostToDevice);
            self.nand.submit(t, flush, NandOp::Program);
        }
        t
    }

    /// Synchronous WAL flush (fsync) — used by clean shutdown, recovery
    /// and durability tests.
    pub fn wal_sync(&mut self, t: Nanos) -> Nanos {
        self.wal_sync_on(0, t)
    }

    /// [`SsdDevice::wal_sync`] against an explicit WAL log.
    pub fn wal_sync_on(&mut self, stream: u32, t: Nanos) -> Nanos {
        let s = self.wal_stream_mut(stream);
        let flush = s.buffered.max(1);
        s.buffered = 0;
        let pcie_done = self.pcie.transfer(t, flush, Direction::HostToDevice);
        let nand_done = self.nand.submit(t, flush, NandOp::Program);
        pcie_done.max(nand_done)
    }

    /// WAL stream bytes that have reached flash (everything handed to
    /// `wal_append` minus the host page cache). This is the crash
    /// durability cut for WAL records — the sync=false ack-vs-durable
    /// gap of the paper's db_bench configuration.
    pub fn wal_durable_watermark(&self) -> u64 {
        self.wal_durable_watermark_on(0)
    }

    /// [`SsdDevice::wal_durable_watermark`] of an explicit WAL log.
    pub fn wal_durable_watermark_on(&self, stream: u32) -> u64 {
        self.wal_streams
            .get(stream as usize)
            .map_or(0, |s| s.total - s.buffered)
    }

    /// Recovery opens a fresh WAL log: stream accounting restarts so the
    /// durable watermark stays aligned with the new log's record offsets
    /// (a second crash must not treat the new log's page-cached tail as
    /// durable just because an earlier life wrote more bytes).
    pub fn wal_reset_stream(&mut self) {
        self.wal_reset_stream_on(0)
    }

    /// [`SsdDevice::wal_reset_stream`] against an explicit WAL log.
    pub fn wal_reset_stream_on(&mut self, stream: u32) {
        *self.wal_stream_mut(stream) = WalStream::default();
    }

    /// Synchronous small metadata write (a fsync'd manifest edit): rides
    /// the latency-sensitive PCIe path and the priority NAND queue.
    pub fn meta_sync_write(&mut self, t: Nanos, bytes: u64) -> Nanos {
        let bytes = bytes.max(64);
        let pcie_done = self.pcie.transfer_small(t, bytes, Direction::HostToDevice);
        let nand_done = self.nand.submit_priority(t, bytes, NandOp::Program);
        pcie_done.max(nand_done)
    }

    /// Power loss at `t`: the host page cache (unsynced WAL bytes) is
    /// lost; NAND contents, the FTL map and the block FS survive; the
    /// capacitor-backed Dev-LSM memtables dump to NAND runs (commercial
    /// KV-SSD power-loss-protection semantics). Host memory is gone —
    /// the engine's `crash()` captures the durable host image separately.
    pub fn crash(&mut self, _t: Nanos) {
        self.power_losses += 1;
        // the buffered bytes never reached flash: remove them from each
        // stream's total so the durable watermarks stay truthful even if
        // read after the crash
        for s in &mut self.wal_streams {
            s.total -= s.buffered;
            s.buffered = 0;
        }
        self.kv.power_loss(&mut self.ftl);
    }

    // ---------------------------------------------------------------
    // Key-value interface (Dev-LSM side)
    // ---------------------------------------------------------------

    /// PUT over the KV interface: DMA the pair in, then the Dev-LSM
    /// ingests it on the ARM core. Returns host-visible ack time.
    pub fn kv_put(&mut self, ns: NamespaceId, t: Nanos, entry: Entry) -> Result<Nanos> {
        let bytes = entry.encoded_len();
        let in_done = self.pcie.transfer_small(t, bytes, Direction::HostToDevice);
        let (ack, arm) = self.kv.put(ns, in_done, entry, &mut self.nand, &mut self.ftl)?;
        self.device_cpu_ns += arm;
        Ok(ack)
    }

    /// GET over the KV interface. Returns (value, host-visible time).
    pub fn kv_get(
        &mut self,
        ns: NamespaceId,
        t: Nanos,
        key: Key,
    ) -> Result<(Option<ValueDesc>, Nanos)> {
        let cmd_done = self.pcie.transfer_small(t, 64, Direction::HostToDevice);
        let (val, dev_done, arm) = self.kv.get(ns, cmd_done, key, &mut self.nand)?;
        self.device_cpu_ns += arm;
        let bytes = val.map(|v| v.value_len().max(64)).unwrap_or(64);
        let out_done = self.pcie.transfer_small(dev_done, bytes, Direction::DeviceToHost);
        Ok((val, out_done))
    }

    /// Iterator-based bulky range scan + chunked DMA out (rollback path,
    /// Fig 9): the device serializes everything, then ships 512 KB DMA
    /// chunks to host memory. Returns (entries, completion time).
    pub fn kv_bulk_scan(&mut self, ns: NamespaceId, t: Nanos) -> Result<(Vec<Entry>, Nanos)> {
        let (entries, ready, arm, payload) =
            self.kv.bulk_scan(ns, t, &mut self.nand)?;
        self.device_cpu_ns += arm;
        let mut done = ready;
        let mut remaining = payload;
        while remaining > 0 {
            let chunk = remaining.min(self.cfg.dma_chunk_bytes);
            done = self.pcie.transfer(done, chunk, Direction::DeviceToHost);
            remaining -= chunk;
        }
        Ok((entries, done))
    }

    /// RESET the Dev-LSM after rollback (Fig 9 step 8).
    pub fn kv_reset(&mut self, ns: NamespaceId, t: Nanos) -> Result<Nanos> {
        let cmd_done = self.pcie.transfer_small(t, 64, Direction::HostToDevice);
        let done = self.kv.reset(ns, cmd_done, &mut self.ftl)?;
        self.device_cpu_ns += 10 * MICROS;
        Ok(done)
    }

    /// Snapshot for host-side dual iterators (range queries).
    pub fn kv_snapshot(&mut self, ns: NamespaceId) -> Result<DevSnapshot> {
        self.kv.snapshot(ns)
    }

    /// Charge one device-side iterator step that crosses a NAND page
    /// (SEEK, or NEXT crossing a page boundary): page read + small DMA.
    pub fn kv_iter_page_read(&mut self, t: Nanos) -> Nanos {
        let page = self.nand.config().page_bytes;
        let nand_done = self.nand.submit(t, page, NandOp::Read);
        self.pcie.transfer_small(nand_done, page, Direction::DeviceToHost)
    }

    /// Zero-cost KV lookup against live device state: no PCIe, NAND or
    /// ARM time is charged and no device counters move. Backs host
    /// block-cache hits on the device write buffer — the host skips the
    /// simulated round-trip but must still observe the live value.
    pub fn kv_peek(&self, ns: NamespaceId, key: Key) -> Option<ValueDesc> {
        self.kv.ns(ns).ok().and_then(|d| d.peek(key))
    }

    /// Zero-cost CDC tail of one KV namespace: buffered entries with
    /// `seq > wm`, sorted by seq (`kv_peek` semantics — no PCIe/NAND/ARM
    /// time, no counters; the replication link charges the transfer).
    pub fn kv_tail_since(&self, ns: NamespaceId, wm: Seq) -> Vec<Entry> {
        self.kv.ns(ns).map(|d| d.tail_since(wm)).unwrap_or_default()
    }

    /// Largest sequence number buffered in one KV namespace (zero-cost).
    pub fn kv_max_seq(&self, ns: NamespaceId) -> Seq {
        self.kv.ns(ns).map(|d| d.max_seq()).unwrap_or(0)
    }

    /// Buffered Dev-LSM size (the Detector/Rollback trigger signal).
    pub fn kv_buffered_bytes(&self, ns: NamespaceId) -> u64 {
        self.kv.ns(ns).map(|d| d.buffered_bytes()).unwrap_or(0)
    }

    pub fn kv_entry_count(&self, ns: NamespaceId) -> usize {
        self.kv.ns(ns).map(|d| d.entry_count()).unwrap_or(0)
    }

    pub fn kv_is_empty(&self, ns: NamespaceId) -> bool {
        self.kv.ns(ns).map(|d| d.is_empty()).unwrap_or(true)
    }

    /// KV-region occupancy fraction (0..1) — backpressure signal for the
    /// controller when the write buffer nears its capacity.
    pub fn kv_occupancy(&self) -> f64 {
        let cap = self.ftl.capacity_pages(Region::KeyValue).max(1);
        self.ftl.allocated_pages(Region::KeyValue) as f64 / cap as f64
    }

    /// Make KV namespaces `0..n` available (one Dev-LSM per KVACCEL
    /// shard). Existing namespaces keep their contents.
    pub fn kv_ensure_namespaces(&mut self, n: usize) {
        while self.kv.namespace_count() < n {
            self.kv.create_namespace(self.cfg.devlsm.clone());
        }
    }

    /// The KV region's byte capacity (the total space the shard arbiter
    /// partitions into grants).
    pub fn kv_region_bytes(&self) -> u64 {
        self.ftl.capacity_pages(Region::KeyValue) * self.cfg.nand.page_bytes
    }

    /// One namespace's share of the KV region (0..1): the arbiter's
    /// hot/idle signal when deciding which shard donates grant capacity.
    /// Approximated from the Dev-LSM's buffered bytes (memtable + runs).
    pub fn kv_ns_occupancy(&self, ns: NamespaceId) -> f64 {
        self.kv_buffered_bytes(ns) as f64 / self.kv_region_bytes().max(1) as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sim::NS_PER_SEC;

    fn small_cfg() -> SsdConfig {
        SsdConfig {
            nand: NandConfig { total_pages: 1 << 22, ..Default::default() },
            ..Default::default()
        }
    }

    fn entry(key: Key, seq: u32) -> Entry {
        Entry::new(key, seq, ValueDesc::new(key, 4096))
    }

    #[test]
    fn file_write_read_delete_cycle() {
        let mut dev = SsdDevice::new(small_cfg());
        let (id, done) = dev.write_file(0, 8 << 20).unwrap();
        assert!(done > 0);
        let rdone = dev.read_file(done, id, 8 << 20);
        assert!(rdone > done);
        dev.delete_file(id).unwrap();
        assert_eq!(dev.fs.file_count(), 0);
    }

    #[test]
    fn write_bandwidth_near_nand_ceiling() {
        let mut dev = SsdDevice::new(small_cfg());
        let bytes: u64 = 512 << 20;
        let (_, done) = dev.write_file(0, bytes).unwrap();
        let bw = bytes as f64 / (done as f64 / NS_PER_SEC as f64);
        let peak = dev.nand.config().peak_program_bw();
        assert!(bw > 0.8 * peak, "bw {bw:.0} vs peak {peak:.0}");
    }

    #[test]
    fn wal_append_is_buffered() {
        let mut dev = SsdDevice::new(small_cfg());
        let before = dev.pcie.stats.h2d_total;
        for i in 0..10 {
            dev.wal_append(i * 1000, 4096);
        }
        // under the 1 MB threshold: nothing hit the device yet
        assert_eq!(dev.pcie.stats.h2d_total, before);
        for i in 0..300 {
            dev.wal_append(i * 1000, 4096);
        }
        assert!(dev.pcie.stats.h2d_total > before);
    }

    #[test]
    fn kv_put_get_roundtrip_with_latency() {
        let mut dev = SsdDevice::new(small_cfg());
        let ack = dev.kv_put(0, 0, entry(7, 1)).unwrap();
        assert!(ack > 0);
        let (v, done) = dev.kv_get(0, ack, 7).unwrap();
        assert_eq!(v, Some(ValueDesc::new(7, 4096)));
        assert!(done > ack);
    }

    #[test]
    fn bulk_scan_chunks_dma() {
        let mut dev = SsdDevice::new(small_cfg());
        let mut t = 0;
        for k in 0..600 {
            t = dev.kv_put(0, t, entry(k, k + 1)).unwrap();
        }
        let before_d2h = dev.pcie.stats.d2h_total;
        let (entries, done) = dev.kv_bulk_scan(0, t).unwrap();
        assert_eq!(entries.len(), 600);
        assert!(done > t);
        // ~600 * 4KB ≈ 2.4 MB came back over PCIe
        assert!(dev.pcie.stats.d2h_total - before_d2h > 2 << 20);
    }

    #[test]
    fn reset_clears_kv_state() {
        let mut dev = SsdDevice::new(small_cfg());
        let t = dev.kv_put(0, 0, entry(1, 1)).unwrap();
        assert!(!dev.kv_is_empty(0));
        dev.kv_reset(0, t).unwrap();
        assert!(dev.kv_is_empty(0));
    }

    #[test]
    fn interfaces_share_nand_bandwidth() {
        // A big block write pushes NAND horizons; a KV flush after it must
        // see the queueing (shared array).
        let mut dev = SsdDevice::new(small_cfg());
        let (_, block_done) = dev.write_file(0, 256 << 20).unwrap();
        let mut t = 0;
        for k in 0..10_000 {
            t = dev.kv_put(0, t, entry(k, k + 1)).unwrap();
            if t > block_done {
                break;
            }
        }
        // Dev-LSM flushed at least once into the same NAND: programmed
        // bytes exceed the block file alone.
        assert!(dev.nand.bytes_programmed >= 256 << 20);
    }

    #[test]
    fn wal_watermark_tracks_page_cache() {
        let mut dev = SsdDevice::new(small_cfg());
        dev.wal_append(0, 4096);
        // still in the page cache: nothing durable yet
        assert_eq!(dev.wal_durable_watermark(), 0);
        dev.wal_sync(0);
        assert_eq!(dev.wal_durable_watermark(), 4096);
        // crossing the writeback threshold makes the backlog durable
        dev.wal_append(0, 2 << 20);
        assert_eq!(dev.wal_durable_watermark(), 4096 + (2 << 20));
    }

    #[test]
    fn crash_drops_page_cache_and_dumps_dev_memtable() {
        let mut dev = SsdDevice::new(small_cfg());
        dev.wal_append(0, 4096);
        let t = dev.kv_put(0, 0, entry(7, 1)).unwrap();
        assert_eq!(dev.kv.ns(0).unwrap().run_count(), 0, "still in device DRAM");
        dev.crash(t);
        assert_eq!(dev.wal_durable_watermark(), 0, "page cache lost");
        assert_eq!(dev.kv.ns(0).unwrap().run_count(), 1, "capacitor dump");
        let (v, _) = dev.kv_get(0, t, 7).unwrap();
        assert_eq!(v, Some(ValueDesc::new(7, 4096)), "redirected write survives");
    }

    #[test]
    fn meta_sync_write_takes_device_time() {
        let mut dev = SsdDevice::new(small_cfg());
        let done = dev.meta_sync_write(0, 48);
        assert!(done > 0);
    }

    #[test]
    fn kv_occupancy_rises_and_resets() {
        let mut dev = SsdDevice::new(small_cfg());
        assert_eq!(dev.kv_occupancy(), 0.0);
        let mut t = 0;
        for k in 0..20_000 {
            t = dev.kv_put(0, t, entry(k, 1)).unwrap();
        }
        assert!(dev.kv_occupancy() > 0.0);
        dev.kv_reset(0, t).unwrap();
        assert_eq!(dev.kv_occupancy(), 0.0);
    }
}
