//! Flash Translation Layer: logical-to-physical page mapping over the
//! disaggregated address space (Fig 8).
//!
//! The logical page range is split at the **disaggregation point** into a
//! block-interface region and a key-value-interface region; each region
//! has its own allocator, so the two interfaces can never hand out
//! overlapping NAND pages (paper §V-D). Mapping-table maintenance charges
//! device-controller CPU time via the caller.
//!
//! GC modeling note: the LSM write pattern above this layer is
//! append-and-trim (whole SST files / whole Dev-LSM runs), which keeps
//! invalidation block-aligned; copy-back GC is therefore intentionally not
//! modeled and write amplification below the FTL is ~1 (see DESIGN.md).

use std::collections::BTreeMap;

use anyhow::{bail, Result};

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Region {
    Block,
    KeyValue,
}

/// One allocated extent of physical pages (contiguous for simplicity —
/// striping happens at the NAND layer).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct Extent {
    pub start_page: u64,
    pub pages: u64,
}

#[derive(Clone, Debug)]
struct RegionState {
    start: u64,
    end: u64,
    next: u64,
    /// Free extents (start -> pages) returned by trims, coalesced lazily.
    free: BTreeMap<u64, u64>,
    free_pages: u64,
    allocated_pages: u64,
}

impl RegionState {
    fn new(start: u64, end: u64) -> Self {
        Self {
            start,
            end,
            next: start,
            free: BTreeMap::new(),
            free_pages: 0,
            allocated_pages: 0,
        }
    }

    fn capacity(&self) -> u64 {
        self.end - self.start
    }

    fn available(&self) -> u64 {
        (self.end - self.next) + self.free_pages
    }

    fn alloc(&mut self, pages: u64) -> Result<Extent> {
        // Bump allocation first; fall back to the free list (first fit).
        if self.end - self.next >= pages {
            let ext = Extent { start_page: self.next, pages };
            self.next += pages;
            self.allocated_pages += pages;
            return Ok(ext);
        }
        let fit = self
            .free
            .iter()
            .find(|(_, &len)| len >= pages)
            .map(|(&s, &len)| (s, len));
        if let Some((s, len)) = fit {
            self.free.remove(&s);
            if len > pages {
                self.free.insert(s + pages, len - pages);
            }
            self.free_pages -= pages;
            self.allocated_pages += pages;
            return Ok(Extent { start_page: s, pages });
        }
        bail!(
            "FTL region exhausted: want {pages} pages, available {}",
            self.available()
        )
    }

    fn trim(&mut self, ext: Extent) {
        self.allocated_pages = self.allocated_pages.saturating_sub(ext.pages);
        self.free_pages += ext.pages;
        self.free.insert(ext.start_page, ext.pages);
        // coalesce neighbours
        let mut merged = true;
        while merged {
            merged = false;
            let keys: Vec<u64> = self.free.keys().copied().collect();
            for s in keys {
                if let Some(&len) = self.free.get(&s) {
                    if let Some(&next_len) = self.free.get(&(s + len)) {
                        self.free.remove(&(s + len));
                        *self.free.get_mut(&s).unwrap() = len + next_len;
                        merged = true;
                    }
                }
            }
        }
    }
}

/// The FTL proper: two regions split at the disaggregation point.
#[derive(Clone, Debug)]
pub struct Ftl {
    block: RegionState,
    kv: RegionState,
    page_bytes: u64,
}

impl Ftl {
    /// `disaggregation_point` is the first logical page of the KV region.
    pub fn new(total_pages: u64, disaggregation_point: u64, page_bytes: u64) -> Self {
        assert!(disaggregation_point <= total_pages);
        Self {
            block: RegionState::new(0, disaggregation_point),
            kv: RegionState::new(disaggregation_point, total_pages),
            page_bytes,
        }
    }

    fn region(&mut self, r: Region) -> &mut RegionState {
        match r {
            Region::Block => &mut self.block,
            Region::KeyValue => &mut self.kv,
        }
    }

    pub fn alloc(&mut self, r: Region, pages: u64) -> Result<Extent> {
        self.region(r).alloc(pages)
    }

    pub fn alloc_bytes(&mut self, r: Region, bytes: u64) -> Result<Extent> {
        let pages = bytes.div_ceil(self.page_bytes).max(1);
        self.alloc(r, pages)
    }

    pub fn trim(&mut self, r: Region, ext: Extent) {
        self.region(r).trim(ext);
    }

    pub fn capacity_pages(&self, r: Region) -> u64 {
        match r {
            Region::Block => self.block.capacity(),
            Region::KeyValue => self.kv.capacity(),
        }
    }

    pub fn available_pages(&self, r: Region) -> u64 {
        match r {
            Region::Block => self.block.available(),
            Region::KeyValue => self.kv.available(),
        }
    }

    pub fn allocated_pages(&self, r: Region) -> u64 {
        match r {
            Region::Block => self.block.allocated_pages,
            Region::KeyValue => self.kv.allocated_pages,
        }
    }

    /// Interfaces can never overlap: the KV region starts where the block
    /// region ends.
    pub fn disaggregation_point(&self) -> u64 {
        self.block.end
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn ftl() -> Ftl {
        Ftl::new(1000, 800, 16 * 1024)
    }

    #[test]
    fn regions_disjoint() {
        let mut f = ftl();
        let a = f.alloc(Region::Block, 10).unwrap();
        let b = f.alloc(Region::KeyValue, 10).unwrap();
        assert!(a.start_page + a.pages <= 800);
        assert!(b.start_page >= 800);
    }

    #[test]
    fn exhaustion_errors() {
        let mut f = ftl();
        assert!(f.alloc(Region::KeyValue, 200).is_ok());
        assert!(f.alloc(Region::KeyValue, 1).is_err());
    }

    #[test]
    fn trim_then_realloc() {
        let mut f = ftl();
        let a = f.alloc(Region::KeyValue, 200).unwrap();
        f.trim(Region::KeyValue, a);
        let b = f.alloc(Region::KeyValue, 150).unwrap();
        assert_eq!(b.pages, 150);
        assert_eq!(f.allocated_pages(Region::KeyValue), 150);
    }

    #[test]
    fn coalescing_allows_big_realloc() {
        let mut f = ftl();
        let a = f.alloc(Region::KeyValue, 100).unwrap();
        let b = f.alloc(Region::KeyValue, 100).unwrap();
        f.trim(Region::KeyValue, a);
        f.trim(Region::KeyValue, b);
        assert!(f.alloc(Region::KeyValue, 200).is_ok());
    }

    #[test]
    fn alloc_bytes_rounds_up() {
        let mut f = ftl();
        let e = f.alloc_bytes(Region::Block, 16 * 1024 + 1).unwrap();
        assert_eq!(e.pages, 2);
    }

    #[test]
    fn accounting() {
        let mut f = ftl();
        assert_eq!(f.capacity_pages(Region::Block), 800);
        assert_eq!(f.available_pages(Region::KeyValue), 200);
        f.alloc(Region::KeyValue, 50).unwrap();
        assert_eq!(f.available_pages(Region::KeyValue), 150);
    }
}
