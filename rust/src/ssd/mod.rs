//! Dual-interface SSD simulator (substitute for the Cosmos+ OpenSSD
//! prototype — see DESIGN.md §2).
//!
//! The SSD's logical NAND space is disaggregated at a configurable point
//! into a **block-interface region** (hosting the Main-LSM's files through
//! a minimal extent filesystem) and a **key-value-interface region**
//! (hosting the in-device Dev-LSM). Both regions share the same NAND
//! geometry/timing, the same FTL, and the same PCIe link — which is
//! exactly what makes the paper's bandwidth-reuse observation work.

pub mod block_if;
pub mod device;
pub mod devlsm;
pub mod ftl;
pub mod kv_if;
pub mod nand;
pub mod pcie;

pub use device::{SsdConfig, SsdDevice};
pub use devlsm::DevLsm;
pub use nand::{NandArray, NandConfig, NandOp};
pub use pcie::{Direction, PcieLink, PcieConfig};
