//! NAND flash array timing model.
//!
//! Geometry follows the Cosmos+ OpenSSD (Table I): 4 channels x 8 ways,
//! 16 KB pages. Per-page operations occupy a (channel, way) pair: the
//! way is busy for the cell operation (tPROG/tR) and the channel bus is
//! serialized for the page transfer. With all 32 ways streaming, the
//! sustained program bandwidth calibrates to the paper's ~630 MB/s device
//! peak.

use crate::sim::{Nanos, MICROS};

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum NandOp {
    Read,
    Program,
}

#[derive(Clone, Debug)]
pub struct NandConfig {
    pub channels: usize,
    pub ways: usize,
    pub page_bytes: u64,
    /// Cell program time per page.
    pub t_prog: Nanos,
    /// Cell read time per page.
    pub t_read: Nanos,
    /// Channel bus transfer time per page (serialized per channel).
    pub t_bus: Nanos,
    /// Total logical capacity in pages (1 TB module by default).
    pub total_pages: u64,
}

impl Default for NandConfig {
    fn default() -> Self {
        // 32 ways * 16 KB / 800 us  = 655 MB/s program ceiling (~paper's
        // 630 MB/s measured peak); reads are faster per cell op.
        Self {
            channels: 4,
            ways: 8,
            page_bytes: 16 * 1024,
            t_prog: 800 * MICROS,
            t_read: 320 * MICROS,
            t_bus: 25 * MICROS,
            total_pages: (1u64 << 40) / (16 * 1024),
        }
    }
}

impl NandConfig {
    pub fn pages_for(&self, bytes: u64) -> u64 {
        bytes.div_ceil(self.page_bytes).max(1)
    }

    /// Peak sequential program bandwidth in bytes/sec (sanity/reporting).
    pub fn peak_program_bw(&self) -> f64 {
        let lanes = (self.channels * self.ways) as f64;
        lanes * self.page_bytes as f64 / (self.t_prog as f64 / 1e9)
    }
}

/// Busy-horizon model of the array. Pages of an I/O are striped
/// round-robin across (channel, way) lanes, the way the OpenSSD firmware
/// stripes sequential writes.
#[derive(Clone, Debug)]
pub struct NandArray {
    cfg: NandConfig,
    /// way_free[ch * ways + w]
    way_free: Vec<Nanos>,
    /// bus_free[ch]
    bus_free: Vec<Nanos>,
    cursor: usize,
    /// total bytes programmed/read (reporting)
    pub bytes_programmed: u64,
    pub bytes_read: u64,
    busy_ns_accum: u128,
}

impl NandArray {
    pub fn new(cfg: NandConfig) -> Self {
        let lanes = cfg.channels * cfg.ways;
        Self {
            way_free: vec![0; lanes],
            bus_free: vec![0; cfg.channels],
            cursor: 0,
            bytes_programmed: 0,
            bytes_read: 0,
            busy_ns_accum: 0,
            cfg,
        }
    }

    pub fn config(&self) -> &NandConfig {
        &self.cfg
    }

    /// Submit an I/O of `bytes` at time `t`; returns completion time of
    /// the last page.
    pub fn submit(&mut self, t: Nanos, bytes: u64, op: NandOp) -> Nanos {
        let pages = self.cfg.pages_for(bytes);
        match op {
            NandOp::Program => self.bytes_programmed += bytes,
            NandOp::Read => self.bytes_read += bytes,
        }
        let mut done = t;
        for _ in 0..pages {
            let lane = self.cursor;
            self.cursor = (self.cursor + 1) % self.way_free.len();
            let ch = lane / self.cfg.ways;
            let end = match op {
                NandOp::Program => {
                    // bus transfer (host data -> cell register), then prog
                    let bus_start = t.max(self.bus_free[ch]).max(self.way_free[lane]);
                    let bus_end = bus_start + self.cfg.t_bus;
                    self.bus_free[ch] = bus_end;
                    let prog_end = bus_end + self.cfg.t_prog;
                    self.way_free[lane] = prog_end;
                    self.busy_ns_accum += (prog_end - bus_start) as u128;
                    prog_end
                }
                NandOp::Read => {
                    // cell read, then bus transfer out
                    let read_start = t.max(self.way_free[lane]);
                    let read_end = read_start + self.cfg.t_read;
                    let bus_start = read_end.max(self.bus_free[ch]);
                    let bus_end = bus_start + self.cfg.t_bus;
                    self.bus_free[ch] = bus_end;
                    self.way_free[lane] = bus_end;
                    self.busy_ns_accum += (bus_end - read_start) as u128;
                    bus_end
                }
            };
            done = done.max(end);
        }
        done
    }

    /// Priority submission (flush writes): real firmware interleaves
    /// streams at page granularity, so a 128 MB flush is not FIFO-queued
    /// behind a multi-GB compaction write — it receives a fair share of
    /// the array immediately. Modeled as service at half the peak rate
    /// while the array is busy (full rate when idle), with the stolen
    /// lane-time pushed onto the bulk horizons to conserve total
    /// bandwidth.
    pub fn submit_priority(&mut self, t: Nanos, bytes: u64, op: NandOp) -> Nanos {
        let pages = self.cfg.pages_for(bytes);
        match op {
            NandOp::Program => self.bytes_programmed += bytes,
            NandOp::Read => self.bytes_read += bytes,
        }
        let lanes = self.way_free.len() as u64;
        let per_page = match op {
            NandOp::Program => self.cfg.t_bus + self.cfg.t_prog,
            NandOp::Read => self.cfg.t_read + self.cfg.t_bus,
        };
        let busy = self.earliest_free() > t;
        // streaming throughput across lanes; halved under contention
        let full_share = per_page / lanes.max(1);
        let per_page_share = if busy { full_share * 2 } else { full_share };
        let done = t + per_page + pages.saturating_sub(1) * per_page_share;
        // conserve capacity: charge the consumed lane-time to the array
        let stolen = pages * per_page / lanes.max(1);
        for lane in self.way_free.iter_mut() {
            *lane = (*lane).max(t) + stolen;
        }
        self.busy_ns_accum += (pages * per_page) as u128;
        done
    }

    /// Earliest time any lane is free (backpressure signal).
    pub fn earliest_free(&self) -> Nanos {
        *self.way_free.iter().min().unwrap()
    }

    /// All-lanes-idle time (drain horizon).
    pub fn drained_at(&self) -> Nanos {
        *self.way_free.iter().max().unwrap()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sim::NS_PER_SEC;

    #[test]
    fn peak_bw_calibration() {
        let cfg = NandConfig::default();
        let bw = cfg.peak_program_bw();
        // Paper device: ~630 MB/s peak. Model ceiling within 600-700 MB/s.
        assert!(
            (600e6..700e6).contains(&bw),
            "program bw {bw:.0} out of calibration band"
        );
    }

    #[test]
    fn sustained_write_matches_ceiling() {
        let cfg = NandConfig::default();
        let mut nand = NandArray::new(cfg.clone());
        let total: u64 = 256 * 1024 * 1024;
        let done = nand.submit(0, total, NandOp::Program);
        let bw = total as f64 / (done as f64 / NS_PER_SEC as f64);
        let peak = cfg.peak_program_bw();
        assert!(
            bw > peak * 0.8 && bw <= peak * 1.05,
            "sustained {bw:.0} vs peak {peak:.0}"
        );
    }

    #[test]
    fn reads_faster_than_writes() {
        let mut a = NandArray::new(NandConfig::default());
        let mut b = NandArray::new(NandConfig::default());
        let size = 64 * 1024 * 1024;
        let r = a.submit(0, size, NandOp::Read);
        let w = b.submit(0, size, NandOp::Program);
        assert!(r < w, "read {r} should beat write {w}");
    }

    #[test]
    fn small_write_latency_single_page() {
        let cfg = NandConfig::default();
        let mut nand = NandArray::new(cfg.clone());
        let done = nand.submit(1000, 4096, NandOp::Program);
        assert_eq!(done, 1000 + cfg.t_bus + cfg.t_prog);
    }

    #[test]
    fn queueing_pushes_completion() {
        let cfg = NandConfig::default();
        let lanes = (cfg.channels * cfg.ways) as u64;
        let mut nand = NandArray::new(cfg.clone());
        // saturate every lane once
        nand.submit(0, lanes * cfg.page_bytes, NandOp::Program);
        let second = nand.submit(0, cfg.page_bytes, NandOp::Program);
        assert!(second > cfg.t_bus + cfg.t_prog);
    }

    #[test]
    fn byte_counters() {
        let mut nand = NandArray::new(NandConfig::default());
        nand.submit(0, 100, NandOp::Program);
        nand.submit(0, 200, NandOp::Read);
        assert_eq!(nand.bytes_programmed, 100);
        assert_eq!(nand.bytes_read, 200);
    }
}
