//! Bench: engine-internal hot paths (memtable insert, SST lookup, bloom
//! probe, iterator next, full put path) — the §Perf L3 profile targets.
//! Run with `cargo bench --bench lsm_micro`.

use kvaccel::bench_util::{black_box, Bencher};
use kvaccel::env::SimEnv;
use kvaccel::lsm::memtable::Memtable;
use kvaccel::lsm::{Entry, LsmDb, LsmOptions, ValueDesc};
use kvaccel::runtime::bloom::{build_bitmap_rust, may_contain};
use kvaccel::runtime::{BloomBuilder, MergeEngine};
use kvaccel::sim::SimRng;
use kvaccel::ssd::SsdConfig;

fn main() {
    let mut b = Bencher::new();
    let mut rng = SimRng::new(5);

    // memtable insert
    let mut mem = Memtable::new();
    let mut s = 0u32;
    b.bench("lsm/memtable_insert_4k", || {
        s = s.wrapping_add(1);
        if mem.len() >= 200_000 {
            mem = Memtable::new(); // bound memory
        }
        mem.insert(Entry::new(s.wrapping_mul(2654435761) / 2, s, ValueDesc::new(s, 4096)));
    });

    // bloom probe
    let keys: Vec<u32> = (0..32_768).map(|_| rng.next_u32() / 2).collect();
    let words = build_bitmap_rust(&keys, 7, 327_680);
    let mut q = 0usize;
    b.bench("lsm/bloom_probe", || {
        q = (q + 1) % keys.len();
        black_box(may_contain(&words, keys[q], 7, 327_680));
    });

    // end-to-end put on the engine (small config, includes WAL+rotation)
    let mut env = SimEnv::new(9, SsdConfig::default());
    let mut db = LsmDb::new(
        LsmOptions::default(),
        MergeEngine::rust(),
        BloomBuilder::rust(),
    );
    let mut t = 0u64;
    let mut k = 0u32;
    b.bench("lsm/put_full_path", || {
        k = k.wrapping_add(1);
        t = db
            .put(&mut env, t, k.wrapping_mul(2654435761) / 2, ValueDesc::new(k, 4096))
            .done;
    });

    // point get after load
    let mut g = 0u32;
    b.bench("lsm/get_hot", || {
        g = g.wrapping_add(1);
        let key = (g % 10_000).wrapping_mul(2654435761) / 2;
        black_box(db.get(&mut env, t, key));
    });
    b.summary();
}
