//! Bench: one scaled-down end-to-end run per paper table/figure — prints
//! the same rows the paper reports. `cargo bench --bench paper_figures`.
//! (Full-scale regeneration: `kvaccel experiment all --scale 1`.)

// real-time harness: wall-clock timing is the point here, so the
// clippy.toml wall-clock ban is lifted for this file
#![allow(clippy::disallowed_methods, clippy::disallowed_types)]

use kvaccel::experiments::{run, EngineMode, ExpContext, ALL_EXPERIMENTS};

fn main() {
    let scale = std::env::var("KVACCEL_BENCH_SCALE")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(0.05);
    let mut ctx = ExpContext::new(scale, 42, EngineMode::Rust)
        .expect("experiment context");
    ctx.out_dir = std::path::PathBuf::from("results/bench");
    println!("paper_figures bench at scale {scale} (600 s * scale per run)\n");
    let wall = std::time::Instant::now();
    for id in ALL_EXPERIMENTS {
        let t = std::time::Instant::now();
        run(&ctx, id).expect(id);
        println!("--- {id} regenerated in {:.1}s wall\n", t.elapsed().as_secs_f64());
    }
    println!(
        "all {} experiments regenerated in {:.1}s wall",
        ALL_EXPERIMENTS.len(),
        wall.elapsed().as_secs_f64()
    );
}
