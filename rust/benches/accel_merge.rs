//! Bench: the compaction-merge offload — XLA artifact vs pure-Rust
//! reference across window sizes (the §Perf L1/L2 numbers in
//! EXPERIMENTS.md). Run with `cargo bench --bench accel_merge`.

use kvaccel::bench_util::{black_box, Bencher};
use kvaccel::runtime::merge::merge_window_rust;
use kvaccel::runtime::{default_artifacts_dir, MergeEngine, XlaRuntime};
use kvaccel::sim::SimRng;
use std::sync::Arc;

fn window(n: usize, seed: u64) -> Vec<(u32, u32)> {
    let mut rng = SimRng::new(seed);
    (0..n).map(|i| (rng.next_u32() / 2, i as u32)).collect()
}

fn main() {
    let mut b = Bencher::new();
    for n in [1024usize, 4096, 16384] {
        let w = window(n, n as u64);
        b.bench_elements(&format!("merge_rust/{n}"), Some(n as u64), || {
            black_box(merge_window_rust(black_box(&w)));
        });
    }
    match XlaRuntime::load(default_artifacts_dir()) {
        Ok(rt) => {
            let engine = MergeEngine::xla(Arc::new(rt)).unwrap();
            for n in [1024usize, 4096, 16384] {
                let w = window(n, n as u64);
                b.bench_elements(&format!("merge_xla/{n}"), Some(n as u64), || {
                    black_box(engine.merge_window(black_box(&w)).unwrap());
                });
            }
        }
        Err(e) => eprintln!("skipping XLA benches (run `make artifacts`): {e:#}"),
    }
    b.summary();
}
