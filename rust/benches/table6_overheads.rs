//! Bench: Table VI — wall-clock overheads of the KVACCEL modules
//! (paper: Detector 1.37 us, key insert 0.45, check 0.20, delete 0.28).
//! Run with `cargo bench --bench table6_overheads`.

use kvaccel::bench_util::{black_box, Bencher};
use kvaccel::env::SimEnv;
use kvaccel::kvaccel::{Detector, DetectorConfig, MetadataConfig, MetadataManager};
use kvaccel::lsm::{LsmDb, LsmOptions, ValueDesc};
use kvaccel::runtime::{BloomBuilder, MergeEngine};
use kvaccel::ssd::SsdConfig;

fn main() {
    let mut env = SimEnv::new(1, SsdConfig::default());
    let mut db = LsmDb::new(
        LsmOptions::small_for_test(),
        MergeEngine::rust(),
        BloomBuilder::rust(),
    );
    let mut t = 0;
    for k in 0..2000u32 {
        t = db.put(&mut env, t, k, ValueDesc::new(k, 4096)).done;
    }

    let mut b = Bencher::new();
    let mut det = Detector::new(DetectorConfig::default());
    let mut i = 0u64;
    b.bench("table6/detector_poll (paper 1.37us)", || {
        i += 1;
        det.sample(&mut env, t + i, &db);
    });

    let mut meta = MetadataManager::new(MetadataConfig::default());
    let mut k = 0u32;
    b.bench("table6/key_insert (paper 0.45us)", || {
        k = k.wrapping_add(1);
        meta.insert(&mut env, t, k);
    });
    let mut q = 0u32;
    b.bench("table6/key_check (paper 0.20us)", || {
        q = q.wrapping_add(7);
        black_box(meta.check(&mut env, t, q));
    });
    let mut d = 0u32;
    b.bench("table6/key_delete (paper 0.28us)", || {
        d = d.wrapping_add(1);
        black_box(meta.delete(&mut env, t, d));
    });
    b.summary();
}
