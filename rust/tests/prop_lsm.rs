//! Property tests for the LSM engine (in-repo driver — the offline image
//! has no proptest): randomized op sequences model-checked against a
//! BTreeMap oracle. Failures print the seed for reproduction.

use std::collections::BTreeMap;

use kvaccel::engine::WriteBatch;
use kvaccel::env::SimEnv;
use kvaccel::lsm::{LsmDb, LsmOptions, ValueDesc};
use kvaccel::runtime::{BloomBuilder, MergeEngine};
use kvaccel::sim::SimRng;
use kvaccel::ssd::SsdConfig;

const CASES: u64 = 25;
const OPS: usize = 1200;

fn value(tag: u32) -> ValueDesc {
    ValueDesc::new(tag, 1024 + (tag % 4096))
}

/// One randomized episode: interleaved put/overwrite/delete/get/scan with
/// random flush waits, checked against the oracle after every read.
fn episode(seed: u64) {
    let mut rng = SimRng::new(seed);
    let mut env = SimEnv::new(seed, SsdConfig::default());
    let mut db = LsmDb::new(
        LsmOptions::small_for_test(),
        MergeEngine::rust(),
        BloomBuilder::rust(),
    );
    // disable slowdown randomly: both policies must preserve semantics
    db.opts.enable_slowdown = rng.gen_ratio(1, 2);
    let key_space = 1 + rng.gen_range_u32(400);
    let mut oracle: BTreeMap<u32, Option<ValueDesc>> = BTreeMap::new();
    let mut t = 0u64;
    for op in 0..OPS {
        match rng.gen_range_u32(100) {
            0..=49 => {
                let k = rng.gen_range_u32(key_space);
                let v = value(op as u32);
                t = db.put(&mut env, t, k, v).done;
                oracle.insert(k, Some(v));
            }
            50..=54 => {
                // grouped writes: puts + deletes through write_batch
                let mut wb = WriteBatch::new();
                let n = 1 + rng.gen_range_u32(8);
                for i in 0..n {
                    let k = rng.gen_range_u32(key_space);
                    if rng.gen_ratio(1, 5) {
                        wb.delete(k);
                        oracle.insert(k, None);
                    } else {
                        let v = value(op as u32 * 16 + i);
                        wb.put(k, v);
                        oracle.insert(k, Some(v));
                    }
                }
                t = db.write_batch(&mut env, t, &wb).done;
            }
            55..=64 => {
                let k = rng.gen_range_u32(key_space);
                t = db.delete(&mut env, t, k).done;
                oracle.insert(k, None);
            }
            65..=89 => {
                let k = rng.gen_range_u32(key_space);
                let (got, nt) = db.get(&mut env, t, k);
                t = nt;
                let want = oracle.get(&k).copied().flatten();
                assert_eq!(got, want, "seed {seed} op {op} get({k})");
            }
            90..=96 => {
                let start = rng.gen_range_u32(key_space);
                let count = 1 + rng.gen_range_u32(20) as usize;
                let (got, nt) = db.scan(&mut env, t, start, count);
                t = nt;
                let want: Vec<(u32, ValueDesc)> = oracle
                    .range(start..)
                    .filter_map(|(&k, &v)| v.map(|v| (k, v)))
                    .take(count)
                    .collect();
                let got_kv: Vec<(u32, ValueDesc)> =
                    got.iter().map(|e| (e.key, e.val)).collect();
                assert_eq!(got_kv, want, "seed {seed} op {op} scan({start},{count})");
            }
            _ => {
                t = db.flush_and_wait(&mut env, t);
            }
        }
    }
    // final full sweep
    t = db.flush_and_wait(&mut env, t);
    for (&k, &want) in &oracle {
        let (got, nt) = db.get(&mut env, t, k);
        t = nt;
        assert_eq!(got, want, "seed {seed} final get({k})");
    }
    // structural invariants
    for l in 1..db.version().levels.len() {
        assert!(db.version().level_disjoint(l), "seed {seed}: L{l} overlap");
    }
    assert_eq!(db.stats.stall_anomalies, 0, "seed {seed}: stall anomaly");
}

#[test]
fn lsm_matches_btreemap_oracle() {
    for case in 0..CASES {
        episode(0xC0FFEE + case);
    }
}

#[test]
fn merge_engine_equivalence_random_windows() {
    // rust merge vs reference across adversarial windows
    use kvaccel::runtime::merge::{kway_merge_dedup, merge_window_rust};
    for seed in 0..200u64 {
        let mut rng = SimRng::new(seed);
        let n = 1 + rng.gen_range_u32(3000) as usize;
        let universe = 1 + rng.gen_range_u32(2000);
        let pairs: Vec<(u32, u32)> = (0..n)
            .map(|i| (rng.gen_range_u32(universe), i as u32))
            .collect();
        let out = merge_window_rust(&pairs);
        // sorted, unique keys, lowest tag per key
        assert!(out.windows(2).all(|w| w[0].0 < w[1].0), "seed {seed}");
        for &(k, tag) in &out {
            let min_tag = pairs
                .iter()
                .filter(|&&(pk, _)| pk == k)
                .map(|&(_, t)| t)
                .min()
                .unwrap();
            assert_eq!(tag, min_tag, "seed {seed} key {k}");
        }
        // kway over split runs == single-window merge
        let mid = n / 2;
        let mut a: Vec<(u32, u32)> = merge_window_rust(&pairs[..mid]);
        let b: Vec<(u32, u32)> = merge_window_rust(&pairs[mid..]);
        a = kway_merge_dedup(vec![a, b]);
        assert_eq!(a, out, "seed {seed} split-merge mismatch");
    }
}

#[test]
fn value_descriptors_roundtrip_bytes() {
    // synthetic values must materialize deterministically and uniquely
    for seed in 0..50u32 {
        let v = ValueDesc::new(seed, 512 + seed);
        let b1 = v.materialize();
        let b2 = v.materialize();
        assert_eq!(b1, b2);
        assert_eq!(b1.len(), (512 + seed) as usize);
    }
}
