//! QoS conformance: (1) a spec with QoS disabled — absent or
//! monitor-only — must produce the *bit-identical* op trace of the
//! pre-QoS scheduler on every engine kind; (2) the noisy-neighbor
//! fairness contract on the plain LSM: with QoS off the abusive tenant
//! degrades the victims' p99 by >= 5x over their isolated baseline,
//! with QoS on the victims stay within 2x of it while the abuser is
//! throttled, shedding, and still making progress (never deadlocked).

use kvaccel::baselines::SystemKind;
use kvaccel::engine::{EngineBuilder, KvEngine};
use kvaccel::env::SimEnv;
use kvaccel::experiments::qos_fairness::run_fairness;
use kvaccel::kvaccel::RollbackScheme;
use kvaccel::lsm::LsmOptions;
use kvaccel::sim::{Nanos, NS_PER_SEC};
use kvaccel::ssd::SsdConfig;
use kvaccel::workload::{
    run_spec_traced, ClientConfig, KeyDist, LoopMode, OpMix, ValueSizeDist, WorkloadSpec,
};

const ENGINES: [&str; 6] = [
    "rocksdb",
    "rocksdb-nosd",
    "adoc",
    "kvaccel",
    "kvaccel-eager",
    "kvaccel-lazy",
];

fn build(name: &str) -> (Box<dyn KvEngine>, SimEnv) {
    let opts = LsmOptions::small_for_test();
    let sys = match name {
        "rocksdb" => EngineBuilder::rocksdb(true).opts(opts).build(),
        "rocksdb-nosd" => EngineBuilder::rocksdb(false).opts(opts).build(),
        "adoc" => EngineBuilder::adoc().opts(opts).build(),
        "kvaccel" => EngineBuilder::kvaccel().opts(opts).build(),
        "kvaccel-eager" => {
            EngineBuilder::kvaccel_scheme(RollbackScheme::Eager).opts(opts).build()
        }
        "kvaccel-lazy" => {
            EngineBuilder::kvaccel_scheme(RollbackScheme::Lazy).opts(opts).build()
        }
        other => panic!("unknown engine {other}"),
    };
    (sys, SimEnv::new(21, SsdConfig::default()))
}

/// Closed + open clients with a mixed op set — every scheduler path the
/// QoS hooks touch (issue, dispatch, queueing, scans, batches).
fn mixed_spec(duration: Nanos) -> WorkloadSpec {
    WorkloadSpec {
        name: "qos-conformance".into(),
        clients: vec![
            ClientConfig::writer(),
            ClientConfig {
                mix: OpMix { put: 3, get: 1, delete: 1, scan: 1, batch: 1 },
                mode: LoopMode::OpenPoisson { ops_per_sec: 1_500.0 },
                dist: KeyDist::Zipfian { theta: 0.9 },
                scan_len: 8,
                seed_tag: 17,
                ..ClientConfig::default()
            },
            ClientConfig::reader()
                .with_mode(LoopMode::OpenFixed { ops_per_sec: 800.0 })
                .with_seed_tag(99),
        ],
        duration,
        start_at: 0,
        key_space: 20_000,
        value_size: 4096,
        value_dist: ValueSizeDist::Fixed(4096),
        seed: 7,
        stop_after_ops: None,
        qos: None,
    }
}

#[test]
fn qos_off_runs_are_bit_identical_to_pre_qos_traces() {
    let base = mixed_spec(NS_PER_SEC / 2);
    // monitor-only: same tenants/rates/SLOs as an enforced config, but
    // accounting only — the op stream must not move by one nanosecond
    let mut monitored = base.clone().with_tenants(2, 400.0, Some(10_000_000));
    monitored.qos = monitored.qos.map(|q| q.monitor_only());

    for name in ENGINES {
        let (mut s1, mut env1) = build(name);
        let (r1, t1) = run_spec_traced(&mut *s1, &mut env1, &base, true);
        let (mut s2, mut env2) = build(name);
        let (r2, t2) = run_spec_traced(&mut *s2, &mut env2, &monitored, true);

        assert_eq!(t1, t2, "{name}: monitor-only QoS perturbed the op trace");
        assert_eq!(r1.writes.total, r2.writes.total, "{name}");
        assert_eq!(r1.reads.total, r2.reads.total, "{name}");
        assert_eq!(r1.write_lat.p99_us, r2.write_lat.p99_us, "{name}");
        assert_eq!(r1.queue_delay.p99_us, r2.queue_delay.p99_us, "{name}");
        // the only difference: the monitored run reports tenants
        assert!(r1.tenants.is_empty(), "{name}: no-QoS run grew tenant rows");
        assert_eq!(r2.tenants.len(), 2, "{name}: tenant breakdown missing");
        // one tenant op per issued op (a batch/scan is one op here, even
        // though the run stats expand them into per-entry counts)
        let per_tenant: u64 = r2.tenants.iter().map(|t| t.ops).sum();
        assert_eq!(per_tenant, t2.len() as u64, "{name}: tenant accounting lost ops");
        for t in &r2.tenants {
            assert_eq!(t.throttled, 0, "{name}: monitor mode throttled");
            assert_eq!(t.shed, 0, "{name}: monitor mode shed");
        }
    }
}

#[test]
fn fairness_contract_holds_on_the_plain_lsm() {
    let f = run_fairness(SystemKind::RocksDb { slowdown: true }, 42, 10).unwrap();

    // the victims' isolated baseline must be sane
    assert!(f.solo_p99_us > 0.0, "degenerate solo run: {f:?}");

    // QoS off: the abuser's flood degrades the victims >= 5x
    assert!(
        f.off_victim_p99_us >= 5.0 * f.solo_p99_us,
        "abuser did not hurt the victims: solo p99 {:.0} us, qos-off p99 {:.0} us",
        f.solo_p99_us,
        f.off_victim_p99_us
    );

    // QoS on: the victims are held within 2x of their isolated baseline
    assert!(
        f.on_victim_p99_us <= 2.0 * f.solo_p99_us,
        "QoS failed to protect the victims: solo p99 {:.0} us, qos-on p99 {:.0} us",
        f.solo_p99_us,
        f.on_victim_p99_us
    );

    // ... while the abuser is throttled and shedding, not deadlocked
    assert!(f.on_abuser_ops > 0, "abuser deadlocked: {f:?}");
    assert!(f.on_abuser_throttled > 0, "bucket never engaged: {f:?}");
    assert!(f.on_abuser_shed > 0, "SLO shedder never engaged: {f:?}");
    assert!(
        f.on_abuser_kops < f.off_abuser_kops,
        "enforcement did not reduce the abuser's throughput: {f:?}"
    );
}

#[test]
fn fairness_run_stays_live_on_kvaccel() {
    // same harness on the accelerated engine: enforcement must compose
    // with device redirection without deadlocking anyone
    let f = run_fairness(
        SystemKind::Kvaccel { scheme: RollbackScheme::Disabled },
        42,
        6,
    )
    .unwrap();
    assert!(f.on_abuser_ops > 0, "abuser deadlocked on kvaccel: {f:?}");
    assert!(f.on_abuser_throttled > 0, "bucket never engaged on kvaccel: {f:?}");
    assert!(
        f.on_victim_p99_us <= f.off_victim_p99_us.max(f.solo_p99_us * 2.0),
        "QoS made the victims worse on kvaccel: {f:?}"
    );
}
