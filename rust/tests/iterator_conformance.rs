//! Cursor/snapshot conformance: the Snapshot + DbIterator API run
//! against every `KvEngine` implementation (plain LSM, ADOC, KVACCEL in
//! all three rollback schemes). Ordering, bounds, reverse iteration,
//! tombstone hiding and snapshot isolation must agree across engines —
//! including a KVACCEL rollback landing in the middle of a scan.

use std::collections::BTreeMap;

use kvaccel::engine::{
    DbIterator, EngineBuilder, EngineStats, IterOptions, KvEngine,
};
use kvaccel::env::SimEnv;
use kvaccel::kvaccel::RollbackScheme;
use kvaccel::lsm::{LsmOptions, ValueDesc};
use kvaccel::sim::Nanos;
use kvaccel::ssd::SsdConfig;

const ENGINES: [&str; 6] = [
    "rocksdb",
    "rocksdb-nosd",
    "adoc",
    "kvaccel",
    "kvaccel-eager",
    "kvaccel-lazy",
];

fn build(name: &str) -> (Box<dyn KvEngine>, SimEnv) {
    let opts = LsmOptions::small_for_test();
    let sys = match name {
        "rocksdb" => EngineBuilder::rocksdb(true).opts(opts).build(),
        "rocksdb-nosd" => EngineBuilder::rocksdb(false).opts(opts).build(),
        "adoc" => EngineBuilder::adoc().opts(opts).build(),
        "kvaccel" => EngineBuilder::kvaccel().opts(opts).build(),
        "kvaccel-eager" => {
            EngineBuilder::kvaccel_scheme(RollbackScheme::Eager).opts(opts).build()
        }
        "kvaccel-lazy" => {
            EngineBuilder::kvaccel_scheme(RollbackScheme::Lazy).opts(opts).build()
        }
        other => panic!("unknown engine {other}"),
    };
    (sys, SimEnv::new(21, SsdConfig::default()))
}

fn v(tag: u32) -> ValueDesc {
    ValueDesc::new(tag, 4096)
}

/// Drain up to `limit` entries ascending from the cursor's position.
fn collect_fwd(
    it: &mut dyn DbIterator,
    env: &mut SimEnv,
    mut t: Nanos,
    limit: usize,
) -> (Vec<(u32, ValueDesc)>, Nanos) {
    let mut out = Vec::new();
    while out.len() < limit {
        let Some(e) = it.entry() else { break };
        out.push((e.key, e.val));
        t = it.next(env, t);
    }
    (out, t)
}

/// Drain up to `limit` entries descending from the cursor's position.
fn collect_bwd(
    it: &mut dyn DbIterator,
    env: &mut SimEnv,
    mut t: Nanos,
    limit: usize,
) -> (Vec<(u32, ValueDesc)>, Nanos) {
    let mut out = Vec::new();
    while out.len() < limit {
        let Some(e) = it.entry() else { break };
        out.push((e.key, e.val));
        t = it.prev(env, t);
    }
    (out, t)
}

/// Puts + deletes + mid-stream flush: enough churn that entries live in
/// the memtable, immutables, L0 and (on KVACCEL) the device buffer.
fn populate(
    sys: &mut dyn KvEngine,
    env: &mut SimEnv,
    oracle: &mut BTreeMap<u32, ValueDesc>,
) -> Nanos {
    let mut t = 0;
    for k in 0..400u32 {
        t = sys.put(env, t, k, v(k)).done;
        oracle.insert(k, v(k));
    }
    t = sys.flush(env, t);
    for k in (0..400u32).step_by(3) {
        t = sys.put(env, t, k, v(k + 1000)).done;
        oracle.insert(k, v(k + 1000));
    }
    for k in (0..400u32).step_by(10) {
        t = sys.delete(env, t, k).done;
        oracle.remove(&k);
    }
    t
}

fn oracle_range(
    oracle: &BTreeMap<u32, ValueDesc>,
    lo: u32,
    hi: u32,
) -> Vec<(u32, ValueDesc)> {
    oracle.range(lo..hi).map(|(&k, &val)| (k, val)).collect()
}

#[test]
fn forward_cursor_matches_oracle_with_bounds() {
    for name in ENGINES {
        let (mut sys, mut env) = build(name);
        let mut oracle = BTreeMap::new();
        let t = populate(&mut *sys, &mut env, &mut oracle);

        let mut it = sys.iter(&mut env, t, IterOptions::range(50, 333));
        let t1 = it.seek_to_first(&mut env, t);
        let (got, _) = collect_fwd(&mut *it, &mut env, t1, usize::MAX);
        assert_eq!(got, oracle_range(&oracle, 50, 333), "{name}: bounded forward scan");
        assert!(
            got.windows(2).all(|w| w[0].0 < w[1].0),
            "{name}: cursor output must be strictly ascending"
        );

        // seek inside the range clamps to bounds on both ends
        let mut it = sys.iter(&mut env, t, IterOptions::range(100, 200));
        let t1 = it.seek(&mut env, t, 0); // below lower bound: clamped up
        let (got, _) = collect_fwd(&mut *it, &mut env, t1, usize::MAX);
        assert_eq!(got, oracle_range(&oracle, 100, 200), "{name}: clamped seek");
    }
}

#[test]
fn scan_wrapper_is_bit_identical_to_cursor_on_interior_ranges() {
    for name in ENGINES {
        let (mut sys, mut env) = build(name);
        let mut oracle = BTreeMap::new();
        let t = populate(&mut *sys, &mut env, &mut oracle);

        for (start, count) in [(0u32, 40usize), (77, 25), (201, 60), (390, 50)] {
            let (scanned, t1) = sys.scan(&mut env, t, start, count);
            let scanned: Vec<(u32, ValueDesc)> =
                scanned.iter().map(|e| (e.key, e.val)).collect();
            // the same range through the cursor API
            let mut it = sys.iter(&mut env, t1, IterOptions::default());
            let t2 = it.seek(&mut env, t1, start);
            let (cursored, _) = collect_fwd(&mut *it, &mut env, t2, count);
            assert_eq!(scanned, cursored, "{name}: scan({start},{count}) != cursor");
            // and both match the oracle (pre-refactor scan semantics)
            let want: Vec<(u32, ValueDesc)> = oracle
                .range(start..)
                .map(|(&k, &val)| (k, val))
                .take(count)
                .collect();
            assert_eq!(scanned, want, "{name}: scan({start},{count}) oracle");
        }
    }
}

#[test]
fn reverse_iteration_mirrors_forward() {
    for name in ENGINES {
        let (mut sys, mut env) = build(name);
        let mut oracle = BTreeMap::new();
        let t = populate(&mut *sys, &mut env, &mut oracle);

        let mut fwd = oracle_range(&oracle, 60, 300);
        let mut it = sys.iter(&mut env, t, IterOptions::range(60, 300));
        let t1 = it.seek_to_last(&mut env, t);
        let (got, _) = collect_bwd(&mut *it, &mut env, t1, usize::MAX);
        fwd.reverse();
        assert_eq!(got, fwd, "{name}: reverse scan must mirror forward");
    }
}

#[test]
fn reverse_option_mirrors_movement_ops() {
    // IterOptions::reverse flips the cursor's principal direction, so a
    // generic Seek + N×Next loop walks the range descending
    for name in ENGINES {
        let (mut sys, mut env) = build(name);
        let mut oracle = BTreeMap::new();
        let t = populate(&mut *sys, &mut env, &mut oracle);

        let mut want = oracle_range(&oracle, 60, 300);
        want.reverse();

        let mut it = sys.iter(&mut env, t, IterOptions::range(60, 300).backward());
        let t1 = it.seek_to_first(&mut env, t); // reverse: lands on the last entry
        let (got, _) = collect_fwd(&mut *it, &mut env, t1, usize::MAX);
        assert_eq!(got, want, "{name}: reverse cursor via generic seek+next");

        // floor-seek through the mirrored seek()
        let mut it = sys.iter(&mut env, t, IterOptions::new().backward());
        it.seek(&mut env, t, 130);
        let floor = oracle.range(..=130u32).next_back().map(|(&k, _)| k);
        assert_eq!(it.key(), floor, "{name}: reverse seek floor-positions");
    }
}

#[test]
fn seek_for_prev_lands_on_floor_and_switches_direction() {
    for name in ENGINES {
        let (mut sys, mut env) = build(name);
        let mut oracle = BTreeMap::new();
        let t = populate(&mut *sys, &mut env, &mut oracle);

        // 130 is deleted (multiple of 10): floor must land below it
        let probe = 130u32;
        let want_floor = oracle.range(..=probe).next_back().map(|(&k, _)| k);
        let mut it = sys.iter(&mut env, t, IterOptions::default());
        let t1 = it.seek_for_prev(&mut env, t, probe);
        assert_eq!(it.key(), want_floor, "{name}: seek_for_prev floor");

        // prev then next returns to the same key (direction switch)
        let floor = it.key().unwrap();
        let t2 = it.prev(&mut env, t1);
        let below = it.key().unwrap();
        assert!(below < floor, "{name}: prev must descend");
        it.next(&mut env, t2);
        assert_eq!(it.key(), Some(floor), "{name}: next after prev returns");
    }
}

#[test]
fn tombstones_hidden_in_both_directions() {
    for name in ENGINES {
        let (mut sys, mut env) = build(name);
        let mut t = 0;
        for k in 0..100u32 {
            t = sys.put(&mut env, t, k, v(k)).done;
        }
        for k in (0..100u32).step_by(7) {
            t = sys.delete(&mut env, t, k).done;
        }
        t = sys.flush(&mut env, t);

        let mut it = sys.iter(&mut env, t, IterOptions::default());
        let t1 = it.seek(&mut env, t, 0);
        let (fwd, _) = collect_fwd(&mut *it, &mut env, t1, usize::MAX);
        assert!(
            fwd.iter().all(|&(k, _)| k % 7 != 0),
            "{name}: deleted keys leaked forward"
        );
        assert_eq!(fwd.len(), 100 - 15, "{name}: live-key count");

        let mut it = sys.iter(&mut env, t, IterOptions::default());
        let t1 = it.seek_to_last(&mut env, t);
        let (bwd, _) = collect_bwd(&mut *it, &mut env, t1, usize::MAX);
        assert!(
            bwd.iter().all(|&(k, _)| k % 7 != 0),
            "{name}: deleted keys leaked backward"
        );
        assert_eq!(bwd.len(), fwd.len(), "{name}: direction-symmetric count");
    }
}

#[test]
fn snapshot_is_isolated_from_later_writes_flushes_and_deletes() {
    for name in ENGINES {
        let (mut sys, mut env) = build(name);
        let mut oracle = BTreeMap::new();
        let mut t = populate(&mut *sys, &mut env, &mut oracle);
        let frozen = oracle.clone();

        let snap = sys.snapshot(&mut env, t);

        // post-snapshot churn: overwrites, fresh keys, deletes, a flush
        for k in 0..400u32 {
            t = sys.put(&mut env, t, k, v(k + 50_000)).done;
        }
        for k in 400..500u32 {
            t = sys.put(&mut env, t, k, v(k)).done;
        }
        for k in (0..400u32).step_by(2) {
            t = sys.delete(&mut env, t, k).done;
        }
        t = sys.flush(&mut env, t);
        assert!(sys.health().live_snapshots >= 1, "{name}: snapshot not tracked");

        let mut it = sys.iter(&mut env, t, IterOptions::new().at(&snap));
        let t1 = it.seek(&mut env, t, 0);
        let (got, _) = collect_fwd(&mut *it, &mut env, t1, usize::MAX);
        let want: Vec<(u32, ValueDesc)> =
            frozen.iter().map(|(&k, &val)| (k, val)).collect();
        assert_eq!(got, want, "{name}: pinned snapshot saw post-snapshot writes");

        // the live view has moved on
        let (live, _) = sys.scan(&mut env, t, 0, 10_000);
        assert!(
            live.iter().any(|e| e.val == v(50_001)),
            "{name}: live view must see the new writes"
        );
    }
}

#[test]
fn kvaccel_scan_stays_consistent_across_a_mid_scan_rollback() {
    for name in ["kvaccel", "kvaccel-eager", "kvaccel-lazy"] {
        let (mut sys, mut env) = build(name);
        let mut t = 0;
        // enough pressure that writes redirect into the device buffer
        for k in 0..4000u32 {
            t = sys.put(&mut env, t, k, v(k)).done;
        }
        let redirected = sys.kvaccel().unwrap().controller.stats.writes_to_dev;
        assert!(redirected > 0, "{name}: setup must redirect writes");

        // open the cursor (pins main + device runs + metadata routing),
        // read a prefix... — the busy probe comes AFTER the cursor's
        // tick, which may finalize a deferred rollback window from the
        // load phase
        let mut it = sys.iter(&mut env, t, IterOptions::default());
        let dev_busy = !env.device.kv_is_empty(0);
        let t1 = it.seek(&mut env, t, 0);
        let (head, t2) = collect_fwd(&mut *it, &mut env, t1, 1000);

        // ...then a rollback lands mid-scan: finish() drains the device
        // buffer into the Main-LSM and resets it (eager/lazy schemes may
        // have already drained it during the load phase)
        let rollbacks_before = sys.kvaccel().unwrap().rollback.stats.rollbacks;
        let t3 = sys.finish(&mut env, t2).unwrap();
        if dev_busy {
            assert!(
                sys.kvaccel().unwrap().rollback.stats.rollbacks > rollbacks_before,
                "{name}: finish must roll back the non-empty device buffer"
            );
        }
        assert!(env.device.kv_is_empty(0), "{name}: device buffer must drain");

        // ...and the open cursor keeps reading the pinned pre-rollback view
        let (tail, _) = collect_fwd(&mut *it, &mut env, t3, usize::MAX);
        let got: Vec<(u32, ValueDesc)> =
            head.into_iter().chain(tail).collect();
        let want: Vec<(u32, ValueDesc)> = (0..4000u32).map(|k| (k, v(k))).collect();
        assert_eq!(
            got, want,
            "{name}: scan spanning a rollback must see one consistent view"
        );
    }
}

#[test]
fn read_amp_counters_accumulate_per_interface() {
    for name in ENGINES {
        let (mut sys, mut env) = build(name);
        let mut t = 0;
        for k in 0..2000u32 {
            t = sys.put(&mut env, t, k, v(k)).done;
        }
        t = sys.flush(&mut env, t);
        let before = sys.scan_amp();
        let (got, _) = sys.scan(&mut env, t, 0, 500);
        assert_eq!(got.len(), 500, "{name}");
        let after = sys.scan_amp();
        assert!(after.seeks > before.seeks, "{name}: seek not counted");
        assert!(
            after.nexts >= before.nexts + 500,
            "{name}: nexts not counted"
        );
        assert!(
            after.main_blocks > before.main_blocks,
            "{name}: flushed data must touch SST blocks"
        );
    }
}

#[test]
fn upper_bound_stops_tail_scans_exactly() {
    // the pre-cursor scan() had no end bound; IterOptions::upper_bound
    // must clip exactly, including at the keyspace tail
    for name in ENGINES {
        let (mut sys, mut env) = build(name);
        let mut t = 0;
        for k in 0..200u32 {
            t = sys.put(&mut env, t, k, v(k)).done;
        }
        let mut it = sys.iter(&mut env, t, IterOptions::new().upper(150));
        let t1 = it.seek(&mut env, t, 140);
        let (got, _) = collect_fwd(&mut *it, &mut env, t1, usize::MAX);
        let keys: Vec<u32> = got.iter().map(|&(k, _)| k).collect();
        assert_eq!(keys, (140..150).collect::<Vec<_>>(), "{name}: upper bound");
    }
}
