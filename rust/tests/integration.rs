//! Cross-module integration tests: full systems on real workloads,
//! durability/recovery drills, ACID-property checks (paper §V-G).

use kvaccel::baselines::SystemKind;
use kvaccel::engine::{EngineBuilder, EngineStats};
use kvaccel::env::SimEnv;
use kvaccel::kvaccel::{KvaccelConfig, KvaccelDb, RollbackScheme};
use kvaccel::lsm::{LsmDb, LsmOptions, ValueDesc};
use kvaccel::runtime::{BloomBuilder, MergeEngine};
use kvaccel::sim::NS_PER_SEC;
use kvaccel::ssd::SsdConfig;
use kvaccel::workload::{fillrandom, readwhilewriting, BenchConfig};

fn small_env(seed: u64) -> SimEnv {
    SimEnv::new(seed, SsdConfig::default())
}

fn v(seed: u32) -> ValueDesc {
    ValueDesc::new(seed, 4096)
}

/// Mid-size engine config: small enough that a few virtual seconds of
/// fillrandom builds real flush/compaction pressure, large enough that
/// the stall machinery behaves like the full config.
fn pressured_opts(threads: usize) -> LsmOptions {
    LsmOptions {
        write_buffer_size: 8 << 20,
        max_bytes_for_level_base: 16 << 20,
        target_file_size: 4 << 20,
        ..LsmOptions::default().with_threads(threads)
    }
}

#[test]
fn kvaccel_beats_baselines_on_write_burst() {
    let cfg = BenchConfig { duration: 5 * NS_PER_SEC, ..Default::default() };
    let mut results = Vec::new();
    for kind in [
        SystemKind::RocksDb { slowdown: true },
        SystemKind::Adoc,
        SystemKind::Kvaccel { scheme: RollbackScheme::Disabled },
    ] {
        let mut sys = EngineBuilder::new(kind).opts(pressured_opts(2)).build();
        let mut env = small_env(42);
        let r = fillrandom(&mut *sys, &mut env, &cfg);
        results.push((kind.label(), r));
    }
    let kops = |n: &str| {
        results
            .iter()
            .find(|(l, _)| l == n)
            .map(|(_, r)| r.write_kops())
            .unwrap()
    };
    assert!(
        kops("KVACCEL") > kops("ADOC"),
        "KVACCEL {} <= ADOC {}",
        kops("KVACCEL"),
        kops("ADOC")
    );
    assert!(kops("KVACCEL") > kops("RocksDB"));
    let kv = results.iter().find(|(l, _)| l == "KVACCEL").unwrap();
    assert_eq!(kv.1.stop_events, 0, "KVACCEL halted");
}

#[test]
fn mixed_workload_all_systems_consistent() {
    let cfg = BenchConfig {
        duration: 3 * NS_PER_SEC,
        key_space: 100_000,
        ..Default::default()
    };
    for kind in [
        SystemKind::RocksDb { slowdown: true },
        SystemKind::Kvaccel { scheme: RollbackScheme::Eager },
    ] {
        let mut sys = EngineBuilder::new(kind)
            .opts(LsmOptions::default().with_threads(2))
            .build();
        let mut env = small_env(7);
        let r = readwhilewriting(&mut *sys, &mut env, &cfg, 8, 2);
        assert!(r.writes.total > 0 && r.reads.total > 0, "{}", kind.label());
    }
}

#[test]
fn wal_recovery_replays_unflushed_writes() {
    let mut env = small_env(3);
    let mut db = LsmDb::new(
        LsmOptions::small_for_test(),
        MergeEngine::rust(),
        BloomBuilder::rust(),
    );
    let mut t = 0;
    for k in 0..500u32 {
        t = db.put(&mut env, t, k, v(k)).done;
    }
    let replay = db.wal_replay();
    assert!(!replay.is_empty(), "expected unflushed WAL entries");
    let mut db2 = LsmDb::new(
        LsmOptions::small_for_test(),
        MergeEngine::rust(),
        BloomBuilder::rust(),
    );
    let mut t2 = 0;
    for e in replay {
        t2 = db2.put(&mut env, t2, e.key, e.val).done;
    }
    let tail_key = 499u32;
    let (got, _) = db2.get(&mut env, t2, tail_key);
    assert_eq!(got, Some(v(tail_key)));
}

#[test]
fn kvaccel_metadata_crash_recovery_end_to_end() {
    let mut env = small_env(5);
    let mut db = KvaccelDb::new(
        LsmOptions::small_for_test(),
        KvaccelConfig::default().with_scheme(RollbackScheme::Disabled),
        MergeEngine::rust(),
        BloomBuilder::rust(),
    );
    let mut t = 0;
    for k in 0..3000u32 {
        t = db.put(&mut env, t, k, v(k)).done;
    }
    let before = db.metadata.len();
    assert!(before > 0, "no redirection happened");
    db.metadata.clear(); // simulated metadata loss
    t = db.recover_metadata(&mut env, t).unwrap();
    assert_eq!(db.metadata.len(), before);
    for k in (0..3000u32).step_by(211) {
        let (got, nt) = db.get(&mut env, t, k);
        t = nt;
        assert_eq!(got, Some(v(k)), "key {k} after metadata recovery");
    }
}

#[test]
fn durability_redirected_writes_survive_in_nand() {
    let mut env = small_env(6);
    let mut db = KvaccelDb::new(
        LsmOptions::small_for_test(),
        KvaccelConfig::default().with_scheme(RollbackScheme::Disabled),
        MergeEngine::rust(),
        BloomBuilder::rust(),
    );
    let mut t = 0;
    for k in 0..3000u32 {
        t = db.put(&mut env, t, k, v(k)).done;
    }
    assert!(!env.device.kv_is_empty(0));
    let (entries, _) = env.device.kv_bulk_scan(0, t).unwrap();
    for e in &entries {
        assert_eq!(e.val.len, 4096);
    }
    assert_eq!(entries.len(), db.metadata.len());
}

#[test]
fn isolation_scans_are_stable_under_concurrent_writes() {
    let mut env = small_env(8);
    let mut db = KvaccelDb::new(
        LsmOptions::small_for_test(),
        KvaccelConfig::default(),
        MergeEngine::rust(),
        BloomBuilder::rust(),
    );
    let mut t = 0;
    for k in (0..1000u32).step_by(2) {
        t = db.put(&mut env, t, k, v(k)).done;
    }
    let (snap, t1) = db.scan(&mut env, t, 0, 100);
    let mut t2 = t1;
    for k in (1..1000u32).step_by(2) {
        t2 = db.put(&mut env, t2, k, v(k)).done;
    }
    assert_eq!(snap.len(), 100);
    assert!(snap.iter().all(|e| e.key % 2 == 0));
    let (snap2, _) = db.scan(&mut env, t2, 0, 100);
    assert!(snap2.iter().take(99).any(|e| e.key % 2 == 1));
}

#[test]
fn sustained_run_holds_invariants() {
    let cfg = BenchConfig {
        duration: 4 * NS_PER_SEC,
        key_space: 200_000,
        ..Default::default()
    };
    let mut sys = EngineBuilder::new(SystemKind::Kvaccel { scheme: RollbackScheme::Eager })
        .opts(pressured_opts(4))
        .build();
    let mut env = small_env(11);
    let r = fillrandom(&mut *sys, &mut env, &cfg);
    assert!(r.writes.total > 10_000);
    let t = sys.finish(&mut env, 10 * NS_PER_SEC).unwrap();
    let db = sys.main_db();
    for l in 1..db.version().levels.len() {
        assert!(db.version().level_disjoint(l), "L{l} overlap");
    }
    let _ = t;
}

#[test]
fn multi_tenant_namespaces_stay_isolated_under_load() {
    use kvaccel::lsm::Entry;
    let mut env = small_env(13);
    let ns2 = env.device.kv.create_namespace(Default::default());
    let mut t = 0;
    for k in 0..500u32 {
        t = env.device.kv_put(0, t, Entry::new(k, k + 1, v(k))).unwrap();
        t = env
            .device
            .kv_put(ns2, t, Entry::new(k, k + 1, v(k ^ 0xFFFF)))
            .unwrap();
    }
    for k in (0..500u32).step_by(37) {
        let (a, _) = env.device.kv_get(0, t, k).unwrap();
        let (b, _) = env.device.kv_get(ns2, t, k).unwrap();
        assert_eq!(a, Some(v(k)));
        assert_eq!(b, Some(v(k ^ 0xFFFF)));
    }
}
