//! Replication conformance: (1) with replication off — or with the
//! engine wrapped in a `ReplicatedDb` under primary reads — every
//! engine kind must produce the *bit-identical* op trace of the
//! pre-replication scheduler (the CDC capture path is synchronous and
//! free, replica work runs on replica environments); (2) a
//! read-your-writes session never observes a value older than one it
//! already saw; (3) for randomized primary crash points the promoted
//! replica serves a per-key prefix-consistent view of the acked
//! writes; (4) Merkle anti-entropy converges a rejoined node's digest
//! to the primary's while shipping strictly fewer bytes than a full
//! resync — including over sharded (multi-stream) engines.

use std::collections::HashMap;

use kvaccel::baselines::SystemKind;
use kvaccel::engine::{EngineBuilder, KvEngine};
use kvaccel::env::SimEnv;
use kvaccel::kvaccel::RollbackScheme;
use kvaccel::lsm::{Key, LsmOptions, ValueDesc};
use kvaccel::repl::{ReadPolicy, ReplConfig, ReplicatedDb};
use kvaccel::shard::ShardPolicy;
use kvaccel::sim::{Nanos, NS_PER_SEC};
use kvaccel::ssd::SsdConfig;
use kvaccel::workload::{
    run_spec_traced, ClientConfig, KeyDist, LoopMode, OpMix, ValueSizeDist, WorkloadSpec,
};

const ENGINE_KINDS: [SystemKind; 6] = [
    SystemKind::RocksDb { slowdown: true },
    SystemKind::RocksDb { slowdown: false },
    SystemKind::Adoc,
    SystemKind::Kvaccel { scheme: RollbackScheme::Eager },
    SystemKind::Kvaccel { scheme: RollbackScheme::Lazy },
    SystemKind::Kvaccel { scheme: RollbackScheme::Disabled },
];

fn plain(kind: SystemKind) -> Box<dyn KvEngine> {
    EngineBuilder::new(kind).opts(LsmOptions::small_for_test()).build()
}

fn replicated(
    kind: SystemKind,
    n: usize,
    policy: ReadPolicy,
    key_space: Key,
) -> ReplicatedDb {
    let cfg = ReplConfig {
        replicas: n,
        read_policy: policy,
        key_space,
        seed: 21,
        ..ReplConfig::default()
    };
    ReplicatedDb::new(cfg, |_| plain(kind))
}

/// Closed + open clients with a mixed op set — every scheduler path the
/// replication hooks touch (puts, gets, deletes, scans, batches).
fn mixed_spec(duration: Nanos) -> WorkloadSpec {
    WorkloadSpec {
        name: "repl-conformance".into(),
        clients: vec![
            ClientConfig::writer(),
            ClientConfig {
                mix: OpMix { put: 3, get: 1, delete: 1, scan: 1, batch: 1 },
                mode: LoopMode::OpenPoisson { ops_per_sec: 1_500.0 },
                dist: KeyDist::Zipfian { theta: 0.9 },
                scan_len: 8,
                seed_tag: 17,
                ..ClientConfig::default()
            },
            ClientConfig::reader()
                .with_mode(LoopMode::OpenFixed { ops_per_sec: 800.0 })
                .with_seed_tag(99),
        ],
        duration,
        start_at: 0,
        key_space: 20_000,
        value_size: 4096,
        value_dist: ValueSizeDist::Fixed(4096),
        seed: 7,
        stop_after_ops: None,
        qos: None,
    }
}

#[test]
fn replicated_primary_timeline_is_bit_identical_to_plain_engine() {
    let spec = mixed_spec(NS_PER_SEC / 2);
    for kind in ENGINE_KINDS {
        let mut s1 = plain(kind);
        let mut env1 = SimEnv::new(21, SsdConfig::default());
        let (r1, t1) = run_spec_traced(&mut *s1, &mut env1, &spec, true);

        let mut s2 = replicated(kind, 2, ReadPolicy::Primary, 20_000);
        let mut env2 = SimEnv::new(21, SsdConfig::default());
        let (r2, t2) = run_spec_traced(&mut s2, &mut env2, &spec, true);

        assert_eq!(t1, t2, "{}: replication perturbed the op trace", kind.label());
        assert_eq!(r1.writes.total, r2.writes.total, "{}", kind.label());
        assert_eq!(r1.reads.total, r2.reads.total, "{}", kind.label());
        assert_eq!(r1.write_lat.p99_us, r2.write_lat.p99_us, "{}", kind.label());
        assert_eq!(r1.queue_delay.p99_us, r2.queue_delay.p99_us, "{}", kind.label());
        // the only difference: the replicated run reports its breakdown
        assert!(r1.replication.is_none(), "{}: plain run grew a repl row", kind.label());
        let rep = r2.replication.expect("replicated run must report");
        assert_eq!(rep.replicas.len(), 2, "{}", kind.label());
        assert!(rep.captured_records > 0, "{}: CDC captured nothing", kind.label());
        assert_eq!(
            rep.replica_reads, 0,
            "{}: primary policy must never route to a replica",
            kind.label()
        );
    }
}

#[test]
fn read_your_writes_never_observes_regression() {
    let mut db = replicated(
        SystemKind::Kvaccel { scheme: RollbackScheme::Disabled },
        3,
        ReadPolicy::ReadYourWrites,
        10_000,
    );
    let mut env = SimEnv::new(5, SsdConfig::default());
    // overwrite a small key set so reads race the shipper; the session's
    // view of each key must only ever move forward
    let mut latest: HashMap<Key, ValueDesc> = HashMap::new();
    let mut observed: HashMap<Key, u32> = HashMap::new();
    let mut t = 0;
    for i in 0..400u32 {
        let k = i % 37;
        let val = ValueDesc::new(i, 512);
        t = db.put(&mut env, t, k, val).done;
        latest.insert(k, val);
        let probe = (i.wrapping_mul(7)) % 37;
        let (got, done) = db.get(&mut env, t, probe);
        t = done;
        if let Some(v) = got {
            let floor = observed.get(&probe).copied().unwrap_or(0);
            assert!(
                v.seed >= floor,
                "key {probe} regressed: saw seed {} after {floor}",
                v.seed
            );
            observed.insert(probe, v.seed);
        }
        // read-your-writes: our own writes are always visible
        if let Some(want) = latest.get(&probe) {
            assert_eq!(got, Some(*want), "own write to {probe} invisible");
        }
    }
    let r = db.results();
    assert_eq!(r.stale_reads, 0, "RYW served a stale view");
    assert!(
        r.replica_reads + r.primary_reads == 400,
        "read routing lost reads: {r:?}"
    );
}

#[test]
fn randomized_crash_points_promote_a_prefix_consistent_replica() {
    // deterministic pseudo-random crash points per engine kind, as in
    // the PR4 recovery conformance: the promoted replica must serve
    // every acked write (the CDC wire drains at failover, so the full
    // acked prefix survives the crash)
    let mut x: u64 = 0x9E37_79B9;
    for kind in [
        SystemKind::RocksDb { slowdown: true },
        SystemKind::Adoc,
        SystemKind::Kvaccel { scheme: RollbackScheme::Disabled },
    ] {
        for trial in 0..3u64 {
            x = x
                .wrapping_mul(6364136223846793005)
                .wrapping_add(1442695040888963407);
            let n = 150 + (x % 600) as u32;
            let mut db = replicated(kind, 2, ReadPolicy::Primary, 701);
            let mut env = SimEnv::new(100 + trial, SsdConfig::default());
            let mut acked: HashMap<Key, Option<ValueDesc>> = HashMap::new();
            let mut t = 0;
            for i in 0..n {
                let k = (i * 37) % 701;
                if i % 23 == 5 {
                    t = db.delete(&mut env, t, k).done;
                    acked.insert(k, None);
                } else {
                    let val = ValueDesc::new(i, 1024);
                    t = db.put(&mut env, t, k, val).done;
                    acked.insert(k, Some(val));
                }
            }
            let fo = db.fail_primary(&mut env, t);
            assert_eq!(fo.crashed, 0, "{} n={n}", kind.label());
            assert_eq!(fo.promoted, 1, "{} n={n}", kind.label());
            let label = format!("{} n={n}", kind.label());
            let mut t2 = t.max(fo.at + fo.blackout_ns);
            for key in 0..701u32 {
                let (got, nt) = db.get(&mut env, t2, key);
                t2 = nt;
                let want = acked.get(&key).copied().flatten();
                assert_eq!(got, want, "{label}: key {key} after promotion");
            }
            // keep writing through the new primary, then rejoin the
            // crashed node and verify the repair closed the divergence
            for i in 0..80u32 {
                let k = (i * 53) % 701;
                let val = ValueDesc::new(50_000 + i, 1024);
                t2 = db.put(&mut env, t2, k, val).done;
            }
            let rep = db.rejoin_crashed(&mut env, t2).expect("rejoin failed");
            assert!(
                rep.hash_bytes + rep.entry_bytes < rep.full_resync_bytes,
                "{label}: repair {} B >= full resync {} B",
                rep.hash_bytes + rep.entry_bytes,
                rep.full_resync_bytes
            );
            let end = db.finish(&mut env, rep.done.max(t2)).unwrap();
            let dp = db.node_digest(&mut env, end, db.primary_index());
            let d0 = db.node_digest(&mut env, end, 0);
            assert_eq!(dp, d0, "{label}: rejoined node still diverged");
        }
    }
}

#[test]
fn anti_entropy_converges_sharded_replicas() {
    // a sharded engine exposes one CDC stream per shard; the shipper
    // must keep per-stream watermarks straight and the Merkle exchange
    // must converge the full multi-shard key space
    let cfg = ReplConfig {
        replicas: 2,
        read_policy: ReadPolicy::Primary,
        key_space: 9_999,
        seed: 11,
        ..ReplConfig::default()
    };
    let mut db = ReplicatedDb::new(cfg, |_| {
        EngineBuilder::new(SystemKind::Kvaccel { scheme: RollbackScheme::Disabled })
            .opts(LsmOptions::small_for_test())
            .sharded(2, ShardPolicy::Range)
            .shard_key_space(10_000)
            .build()
    });
    let mut env = SimEnv::new(11, SsdConfig::default());
    let mut t = 0;
    for i in 0..400u32 {
        let k = (i * 97) % 10_000;
        t = db.put(&mut env, t, k, ValueDesc::new(i, 512)).done;
    }
    let end = db.finish(&mut env, t).unwrap();
    assert_eq!(db.applied_records(1), db.log_len(), "replica lagging after drain");
    let d0 = db.node_digest(&mut env, end, 0);
    let d1 = db.node_digest(&mut env, end, 1);
    assert_eq!(d0, d1, "sharded replica diverged from its primary");

    // crash/promote/rejoin across the shard boundary
    let fo = db.fail_primary(&mut env, end);
    let mut t2 = end.max(fo.at + fo.blackout_ns);
    for i in 0..60u32 {
        let k = (i * 31) % 10_000;
        t2 = db.put(&mut env, t2, k, ValueDesc::new(90_000 + i, 512)).done;
    }
    let rep = db.rejoin_crashed(&mut env, t2).expect("rejoin failed");
    assert!(
        rep.hash_bytes + rep.entry_bytes < rep.full_resync_bytes,
        "sharded repair must beat a full resync"
    );
    let end2 = db.finish(&mut env, rep.done.max(t2)).unwrap();
    let dp = db.node_digest(&mut env, end2, db.primary_index());
    let dr = db.node_digest(&mut env, end2, fo.crashed);
    assert_eq!(dp, dr, "sharded rejoin left divergence");
}
