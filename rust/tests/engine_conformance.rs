//! Engine conformance: one shared test suite run against every
//! `KvEngine` implementation (plain LSM, ADOC, KVACCEL in all three
//! rollback schemes). Put/get/delete/write_batch/scan semantics must
//! agree across engines — the API contract behind the paper's claim
//! that KVACCEL swaps in behind the same KV interface.

use std::collections::BTreeMap;

use kvaccel::engine::{EngineBuilder, EngineStats, KvEngine, WriteBatch};
use kvaccel::env::SimEnv;
use kvaccel::kvaccel::RollbackScheme;
use kvaccel::lsm::{LsmOptions, ValueDesc};
use kvaccel::sim::{Nanos, SimRng};
use kvaccel::ssd::SsdConfig;

const ENGINES: [&str; 6] = [
    "rocksdb",
    "rocksdb-nosd",
    "adoc",
    "kvaccel",
    "kvaccel-eager",
    "kvaccel-lazy",
];

fn build(name: &str) -> (Box<dyn KvEngine>, SimEnv) {
    let opts = LsmOptions::small_for_test();
    let sys = match name {
        "rocksdb" => EngineBuilder::rocksdb(true).opts(opts).build(),
        "rocksdb-nosd" => EngineBuilder::rocksdb(false).opts(opts).build(),
        "adoc" => EngineBuilder::adoc().opts(opts).build(),
        "kvaccel" => EngineBuilder::kvaccel().opts(opts).build(),
        "kvaccel-eager" => {
            EngineBuilder::kvaccel_scheme(RollbackScheme::Eager).opts(opts).build()
        }
        "kvaccel-lazy" => {
            EngineBuilder::kvaccel_scheme(RollbackScheme::Lazy).opts(opts).build()
        }
        other => panic!("unknown engine {other}"),
    };
    (sys, SimEnv::new(21, SsdConfig::default()))
}

fn v(tag: u32) -> ValueDesc {
    ValueDesc::new(tag, 4096)
}

#[test]
fn put_get_delete_roundtrip() {
    for name in ENGINES {
        let (mut sys, mut env) = build(name);
        let mut t = 0;
        t = sys.put(&mut env, t, 1, v(10)).done;
        t = sys.put(&mut env, t, 2, v(20)).done;
        t = sys.put(&mut env, t, 1, v(11)).done; // overwrite
        t = sys.delete(&mut env, t, 2).done;
        let (a, t1) = sys.get(&mut env, t, 1);
        let (b, t2) = sys.get(&mut env, t1, 2);
        let (c, _) = sys.get(&mut env, t2, 3);
        assert_eq!(a, Some(v(11)), "{name}: overwrite must win");
        assert_eq!(b, None, "{name}: deleted key must read absent");
        assert_eq!(c, None, "{name}: missing key must read absent");
    }
}

#[test]
fn delete_stays_deleted_across_flush_and_compaction() {
    for name in ENGINES {
        let (mut sys, mut env) = build(name);
        let mut t = 0;
        t = sys.put(&mut env, t, 7, v(1)).done;
        t = sys.delete(&mut env, t, 7).done;
        // disjoint-key churn forces flushes + compactions underneath
        for k in 0..2500u32 {
            t = sys.put(&mut env, t, 1000 + (k % 601), v(k)).done;
        }
        t = sys.finish(&mut env, t).unwrap();
        assert!(
            sys.db_stats().flush_count > 0,
            "{name}: churn should have flushed"
        );
        let (got, nt) = sys.get(&mut env, t, 7);
        t = nt;
        assert_eq!(got, None, "{name}: deleted key resurfaced after finish");
        // delete of a live key after heavy churn also sticks
        t = sys.delete(&mut env, t, 1000).done;
        t = sys.finish(&mut env, t).unwrap();
        let (got, _) = sys.get(&mut env, t, 1000);
        assert_eq!(got, None, "{name}: post-churn delete lost");
    }
}

#[test]
fn write_batch_agrees_with_sequential_puts() {
    for name in ENGINES {
        let (mut batched, mut env_a) = build(name);
        let (mut sequential, mut env_b) = build(name);
        let mut oracle: BTreeMap<u32, Option<ValueDesc>> = BTreeMap::new();
        let (mut ta, mut tb) = (0, 0);
        let mut rng = SimRng::new(77);
        for round in 0..40u32 {
            let mut wb = WriteBatch::new();
            for i in 0..8u32 {
                let k = rng.gen_range_u32(300);
                if rng.gen_ratio(1, 6) {
                    wb.delete(k);
                    tb = sequential.delete(&mut env_b, tb, k).done;
                    oracle.insert(k, None);
                } else {
                    let val = v(round * 8 + i);
                    wb.put(k, val);
                    tb = sequential.put(&mut env_b, tb, k, val).done;
                    oracle.insert(k, Some(val));
                }
            }
            ta = batched.write_batch(&mut env_a, ta, &wb).done;
        }
        ta = batched.finish(&mut env_a, ta).unwrap();
        tb = sequential.finish(&mut env_b, tb).unwrap();
        for (&k, &want) in &oracle {
            let (ga, na) = batched.get(&mut env_a, ta, k);
            ta = na;
            let (gb, nb) = sequential.get(&mut env_b, tb, k);
            tb = nb;
            assert_eq!(ga, want, "{name}: batched get({k})");
            assert_eq!(gb, want, "{name}: sequential get({k})");
        }
    }
}

#[test]
fn scan_is_sorted_snapshot_of_live_keys() {
    for name in ENGINES {
        let (mut sys, mut env) = build(name);
        let mut oracle: BTreeMap<u32, ValueDesc> = BTreeMap::new();
        let mut t = 0;
        for k in (0..400u32).step_by(2) {
            t = sys.put(&mut env, t, k, v(k)).done;
            oracle.insert(k, v(k));
        }
        for k in (0..400u32).step_by(10) {
            t = sys.delete(&mut env, t, k).done;
            oracle.remove(&k);
        }
        let (got, t1) = sys.scan(&mut env, t, 100, 50);
        let want: Vec<(u32, ValueDesc)> = oracle
            .range(100..)
            .map(|(&k, &val)| (k, val))
            .take(50)
            .collect();
        let got_kv: Vec<(u32, ValueDesc)> = got.iter().map(|e| (e.key, e.val)).collect();
        assert_eq!(got_kv, want, "{name}: scan mismatch");

        // snapshot isolation: the scan's result set was pinned at issue
        // time; writes after t1 don't retroactively change it
        let (snap, t2) = sys.scan(&mut env, t1, 0, 1000);
        let mut t3 = t2;
        for k in (1..400u32).step_by(2) {
            t3 = sys.put(&mut env, t3, k, v(k)).done;
        }
        assert!(
            snap.iter().all(|e| e.key % 2 == 0),
            "{name}: snapshot must not contain post-scan writes"
        );
        let _ = t3;
    }
}

#[test]
fn every_engine_matches_one_oracle_stream() {
    // the same randomized op stream, replayed on every engine, must
    // produce byte-identical user-visible state
    let mut streams: Vec<(String, Vec<(u32, ValueDesc)>)> = Vec::new();
    for name in ENGINES {
        let (mut sys, mut env) = build(name);
        let mut rng = SimRng::new(1234);
        let mut oracle: BTreeMap<u32, Option<ValueDesc>> = BTreeMap::new();
        let mut t: Nanos = 0;
        for op in 0..800u32 {
            match rng.gen_range_u32(10) {
                0..=5 => {
                    let k = rng.gen_range_u32(500);
                    t = sys.put(&mut env, t, k, v(op)).done;
                    oracle.insert(k, Some(v(op)));
                }
                6 => {
                    let k = rng.gen_range_u32(500);
                    t = sys.delete(&mut env, t, k).done;
                    oracle.insert(k, None);
                }
                7..=8 => {
                    let mut wb = WriteBatch::new();
                    for i in 0..4u32 {
                        let k = rng.gen_range_u32(500);
                        wb.put(k, v(op * 4 + i));
                        oracle.insert(k, Some(v(op * 4 + i)));
                    }
                    t = sys.write_batch(&mut env, t, &wb).done;
                }
                _ => {
                    t = sys.flush(&mut env, t);
                }
            }
        }
        t = sys.finish(&mut env, t).unwrap();
        // verify against the oracle, and record the full live state
        let (all, _) = sys.scan(&mut env, t, 0, 10_000);
        let want: Vec<(u32, ValueDesc)> = oracle
            .iter()
            .filter_map(|(&k, &val)| val.map(|val| (k, val)))
            .collect();
        let got: Vec<(u32, ValueDesc)> = all.iter().map(|e| (e.key, e.val)).collect();
        assert_eq!(got, want, "{name}: final state diverges from oracle");
        streams.push((name.to_string(), got));
    }
    // all engines identical (transitively via the oracle, but assert
    // pairwise anyway for a readable failure)
    for pair in streams.windows(2) {
        assert_eq!(
            pair[0].1, pair[1].1,
            "{} and {} diverge",
            pair[0].0, pair[1].0
        );
    }
}

#[test]
fn stats_and_health_are_uniform() {
    for name in ENGINES {
        let (mut sys, mut env) = build(name);
        let mut t = 0;
        for k in 0..300u32 {
            t = sys.put(&mut env, t, k, v(k)).done;
        }
        t = sys.delete(&mut env, t, 0).done;
        let mut wb = WriteBatch::new();
        wb.put(1000, v(1)).delete(1000);
        t = sys.write_batch(&mut env, t, &wb).done;
        let stats = sys.db_stats();
        let kv_redirected = sys
            .kvaccel()
            .map_or(0, |k| k.controller.stats.writes_to_dev);
        // every write op lands exactly once in the main-path counter or
        // the dev-redirect counter: 300 puts + 1 delete + a 2-op batch
        // (puts counts tombstones too, like RocksDB)
        assert_eq!(
            stats.puts + kv_redirected,
            303,
            "{name}: puts {} + redirected {kv_redirected} must cover 303 ops",
            stats.puts
        );
        // logical deletes are counted uniformly regardless of route:
        // one single-op delete + one batched delete
        assert_eq!(stats.deletes, 2, "{name}: delete counter not uniform");
        let h = sys.health();
        assert!(
            h.memtable_bytes > 0 || h.l0_files > 0 || h.imm_memtables > 0 || kv_redirected > 0,
            "{name}: health shows an empty store after 300 writes"
        );
        let _ = t;
    }
}
