//! Property tests for the full KVACCEL system: random op streams across
//! redirect/rollback cycles model-checked against a BTreeMap oracle —
//! the paper's consistency claim (§V-G) under adversarial interleaving.

use std::collections::BTreeMap;

use kvaccel::engine::WriteBatch;
use kvaccel::env::SimEnv;
use kvaccel::kvaccel::{KvaccelConfig, KvaccelDb, RollbackScheme};
use kvaccel::lsm::{LsmOptions, ValueDesc};
use kvaccel::runtime::{BloomBuilder, MergeEngine};
use kvaccel::sim::SimRng;
use kvaccel::ssd::SsdConfig;

const CASES: u64 = 15;
const OPS: usize = 1500;

fn value(tag: u32) -> ValueDesc {
    ValueDesc::new(tag, 4096)
}

fn episode(seed: u64, scheme: RollbackScheme) {
    let mut rng = SimRng::new(seed);
    let mut env = SimEnv::new(seed, SsdConfig::default());
    let mut db = KvaccelDb::new(
        LsmOptions::small_for_test(),
        KvaccelConfig::default().with_scheme(scheme),
        MergeEngine::rust(),
        BloomBuilder::rust(),
    );
    let key_space = 1 + rng.gen_range_u32(600);
    let mut oracle: BTreeMap<u32, Option<ValueDesc>> = BTreeMap::new();
    let mut t = 0u64;
    for op in 0..OPS {
        match rng.gen_range_u32(100) {
            0..=54 => {
                let k = rng.gen_range_u32(key_space);
                let v = value(op as u32);
                t = db.put(&mut env, t, k, v).done;
                oracle.insert(k, Some(v));
            }
            55..=59 => {
                // batched writes flow through the detector/controller as
                // one unit (batched redirection during stalls)
                let mut wb = WriteBatch::new();
                let n = 1 + rng.gen_range_u32(8);
                for i in 0..n {
                    let k = rng.gen_range_u32(key_space);
                    if rng.gen_ratio(1, 5) {
                        wb.delete(k);
                        oracle.insert(k, None);
                    } else {
                        let v = value(op as u32 * 16 + i);
                        wb.put(k, v);
                        oracle.insert(k, Some(v));
                    }
                }
                t = db.write_batch(&mut env, t, &wb).done;
            }
            60..=69 => {
                let k = rng.gen_range_u32(key_space);
                t = db.delete(&mut env, t, k).done;
                oracle.insert(k, None);
            }
            70..=94 => {
                let k = rng.gen_range_u32(key_space);
                let (got, nt) = db.get(&mut env, t, k);
                t = nt;
                let want = oracle.get(&k).copied().flatten();
                assert_eq!(
                    got, want,
                    "seed {seed} scheme {scheme:?} op {op} get({k})"
                );
            }
            _ => {
                let start = rng.gen_range_u32(key_space);
                let count = 1 + rng.gen_range_u32(16) as usize;
                let (got, nt) = db.scan(&mut env, t, start, count);
                t = nt;
                let want: Vec<(u32, ValueDesc)> = oracle
                    .range(start..)
                    .filter_map(|(&k, &v)| v.map(|v| (k, v)))
                    .take(count)
                    .collect();
                let got_kv: Vec<(u32, ValueDesc)> =
                    got.iter().map(|e| (e.key, e.val)).collect();
                assert_eq!(
                    got_kv, want,
                    "seed {seed} scheme {scheme:?} op {op} scan({start})"
                );
            }
        }
    }
    // finish: rollback + drain, then the aggregate store must equal the
    // oracle exactly (aggregation property, paper §V-B)
    let mut t = db.finish(&mut env, t).unwrap();
    assert!(env.device.kv_is_empty(db.namespace()), "seed {seed}: dev not drained");
    assert!(db.metadata.is_empty(), "seed {seed}: metadata not cleared");
    for (&k, &want) in &oracle {
        let (got, nt) = db.get(&mut env, t, k);
        t = nt;
        assert_eq!(got, want, "seed {seed} scheme {scheme:?} final get({k})");
    }
}

#[test]
fn kvaccel_eager_matches_oracle() {
    for case in 0..CASES {
        episode(0xABCD + case, RollbackScheme::Eager);
    }
}

#[test]
fn kvaccel_lazy_matches_oracle() {
    for case in 0..CASES {
        episode(0xBEEF + case, RollbackScheme::Lazy);
    }
}

#[test]
fn kvaccel_disabled_rollback_matches_oracle() {
    for case in 0..CASES {
        episode(0xD00D + case, RollbackScheme::Disabled);
    }
}

#[test]
fn rollback_is_idempotent_under_repeated_finish() {
    for seed in 0..5u64 {
        let mut env = SimEnv::new(seed, SsdConfig::default());
        let mut db = KvaccelDb::new(
            LsmOptions::small_for_test(),
            KvaccelConfig::default().with_scheme(RollbackScheme::Disabled),
            MergeEngine::rust(),
            BloomBuilder::rust(),
        );
        let mut t = 0;
        for k in 0..2000u32 {
            t = db.put(&mut env, t, k, value(k)).done;
        }
        t = db.finish(&mut env, t).unwrap();
        let t2 = db.finish(&mut env, t).unwrap(); // second finish: no-op
        for k in (0..2000u32).step_by(191) {
            let (got, nt) = db.get(&mut env, t2, k);
            t = nt;
            assert_eq!(got, Some(value(k)), "seed {seed} key {k}");
        }
    }
}
