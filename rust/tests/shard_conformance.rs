//! Sharded-engine conformance: `ShardedDb` must satisfy the same
//! `KvEngine` contract as the single-shard engines — the engine suite
//! (put/get/delete/write_batch/scan), the cursor suite (ordering,
//! bounds, reverse, direction switches, tombstones, snapshot isolation)
//! and the recovery suite (clean close, prefix-consistent crash
//! recovery, double crash) — for both routing policies at N=1 and N=4,
//! plus the shard-specific contracts: cross-shard batch routing
//! atomicity, coherent snapshot horizons under concurrent puts,
//! crash-mid-rebalance grant recovery, and the idle-shard read-amp
//! no-double-charge guarantee. N=1 range sharding must be bit-compatible
//! with the unsharded engine on the fillrandom preset.

use std::collections::{BTreeMap, HashMap};

use kvaccel::baselines::SystemKind;
use kvaccel::engine::{
    DbIterator, EngineBuilder, EngineStats, IterOptions, KvEngine, ScanAmp,
    WriteBatch,
};
use kvaccel::env::SimEnv;
use kvaccel::kvaccel::{KvaccelConfig, RollbackScheme};
use kvaccel::lsm::{Key, LsmOptions, ValueDesc};
use kvaccel::runtime::{BloomBuilder, MergeEngine};
use kvaccel::shard::{ShardPolicy, ShardSpec, ShardedDb};
use kvaccel::sim::{Nanos, SimRng, NS_PER_SEC};
use kvaccel::ssd::SsdConfig;
use kvaccel::workload::{self, BenchConfig, ClientConfig, WorkloadSpec};

const KEY_SPACE: Key = 50_000;

const KINDS: [SystemKind; 2] = [
    SystemKind::RocksDb { slowdown: true },
    SystemKind::Kvaccel { scheme: RollbackScheme::Disabled },
];

const POLICIES: [ShardPolicy; 2] = [ShardPolicy::Range, ShardPolicy::Hash];

fn sharded(kind: SystemKind, n: usize, policy: ShardPolicy) -> (Box<dyn KvEngine>, SimEnv) {
    (
        EngineBuilder::new(kind)
            .opts(LsmOptions::small_for_test())
            .sharded(n, policy)
            .shard_key_space(KEY_SPACE)
            .build(),
        SimEnv::new(21, SsdConfig::default()),
    )
}

fn label(kind: SystemKind, n: usize, policy: ShardPolicy) -> String {
    format!("{} x{} {}", kind.label(), n, policy.label())
}

fn v(tag: u32) -> ValueDesc {
    ValueDesc::new(tag, 4096)
}

fn collect_fwd(
    it: &mut dyn DbIterator,
    env: &mut SimEnv,
    mut t: Nanos,
    limit: usize,
) -> (Vec<(u32, ValueDesc)>, Nanos) {
    let mut out = Vec::new();
    while out.len() < limit {
        let Some(e) = it.entry() else { break };
        out.push((e.key, e.val));
        t = it.next(env, t);
    }
    (out, t)
}

// ---------------------------------------------------------------------
// Engine contract
// ---------------------------------------------------------------------

#[test]
fn put_get_delete_roundtrip_all_configs() {
    for kind in KINDS {
        for policy in POLICIES {
            for n in [1usize, 4] {
                let (mut sys, mut env) = sharded(kind, n, policy);
                let tag = label(kind, n, policy);
                let mut t = 0;
                t = sys.put(&mut env, t, 1, v(10)).done;
                t = sys.put(&mut env, t, 30_001, v(20)).done; // another shard (range)
                t = sys.put(&mut env, t, 1, v(11)).done;
                t = sys.delete(&mut env, t, 30_001).done;
                let (a, t1) = sys.get(&mut env, t, 1);
                let (b, t2) = sys.get(&mut env, t1, 30_001);
                let (c, _) = sys.get(&mut env, t2, 40_999);
                assert_eq!(a, Some(v(11)), "{tag}: overwrite must win");
                assert_eq!(b, None, "{tag}: deleted key must read absent");
                assert_eq!(c, None, "{tag}: missing key must read absent");
            }
        }
    }
}

#[test]
fn randomized_op_stream_matches_oracle_and_unsharded() {
    // the same randomized op stream on every sharded config must yield
    // the same user-visible state as a BTreeMap oracle — and therefore
    // as the unsharded engines (transitively, via engine_conformance)
    for kind in KINDS {
        for policy in POLICIES {
            for n in [1usize, 4] {
                let (mut sys, mut env) = sharded(kind, n, policy);
                let tag = label(kind, n, policy);
                let mut rng = SimRng::new(1234);
                let mut oracle: BTreeMap<u32, Option<ValueDesc>> = BTreeMap::new();
                let mut t: Nanos = 0;
                for op in 0..800u32 {
                    match rng.gen_range_u32(10) {
                        0..=5 => {
                            let k = rng.gen_range_u32(KEY_SPACE);
                            t = sys.put(&mut env, t, k, v(op)).done;
                            oracle.insert(k, Some(v(op)));
                        }
                        6 => {
                            let k = rng.gen_range_u32(KEY_SPACE);
                            t = sys.delete(&mut env, t, k).done;
                            oracle.insert(k, None);
                        }
                        7..=8 => {
                            let mut wb = WriteBatch::new();
                            for i in 0..6u32 {
                                let k = rng.gen_range_u32(KEY_SPACE);
                                wb.put(k, v(op * 6 + i));
                                oracle.insert(k, Some(v(op * 6 + i)));
                            }
                            t = sys.write_batch(&mut env, t, &wb).done;
                        }
                        _ => {
                            t = sys.flush(&mut env, t);
                        }
                    }
                }
                t = sys.finish(&mut env, t).unwrap();
                let (all, _) = sys.scan(&mut env, t, 0, 100_000);
                let want: Vec<(u32, ValueDesc)> = oracle
                    .iter()
                    .filter_map(|(&k, &val)| val.map(|val| (k, val)))
                    .collect();
                let got: Vec<(u32, ValueDesc)> =
                    all.iter().map(|e| (e.key, e.val)).collect();
                assert_eq!(got, want, "{tag}: final state diverges from oracle");
            }
        }
    }
}

#[test]
fn cross_shard_batch_routes_every_op_exactly_once() {
    for kind in KINDS {
        for policy in POLICIES {
            let (mut sys, mut env) = sharded(kind, 4, policy);
            let tag = label(kind, 4, policy);
            // one batch spanning the whole keyspace: every shard gets a
            // sub-batch through its own admission gate
            let mut wb = WriteBatch::new();
            for i in 0..64u32 {
                wb.put(i * (KEY_SPACE / 64), v(i));
            }
            wb.delete(0);
            let r = sys.write_batch(&mut env, 0, &wb);
            assert_eq!(r.ops, 65, "{tag}: batch reports all ops");
            // every op applied exactly once, on the shard that owns it
            let stats = sys.db_stats();
            assert_eq!(
                stats.puts + sys.redirected_writes(),
                65,
                "{tag}: puts {} + redirected {} must cover the batch",
                stats.puts,
                sys.redirected_writes()
            );
            assert_eq!(stats.deletes, 1, "{tag}: delete counted once");
            let mut t = sys.finish(&mut env, r.done).unwrap();
            for i in 1..64u32 {
                let key = i * (KEY_SPACE / 64);
                let (got, nt) = sys.get(&mut env, t, key);
                t = nt;
                assert_eq!(got, Some(v(i)), "{tag}: key {key}");
            }
            let (gone, _) = sys.get(&mut env, t, 0);
            assert_eq!(gone, None, "{tag}: batched delete must win");
            // with 4 shards and 65 spread keys, more than one shard must
            // have taken writes
            let sh = sys.sharded().expect("sharded engine");
            let active = sh
                .shard_reports(&env)
                .iter()
                .filter(|rep| rep.puts + rep.redirected > 0)
                .count();
            assert!(active > 1, "{tag}: batch never crossed a shard boundary");
        }
    }
}

// ---------------------------------------------------------------------
// Cursor contract
// ---------------------------------------------------------------------

/// Churn both sides of several shard boundaries, with deletes.
fn populate(
    sys: &mut dyn KvEngine,
    env: &mut SimEnv,
    oracle: &mut BTreeMap<u32, ValueDesc>,
) -> Nanos {
    let mut t = 0;
    for k in (0..KEY_SPACE).step_by(13) {
        t = sys.put(env, t, k, v(k)).done;
        oracle.insert(k, v(k));
    }
    for k in (0..KEY_SPACE).step_by(91) {
        t = sys.delete(env, t, k).done;
        oracle.remove(&k);
    }
    for k in (7..KEY_SPACE).step_by(29) {
        t = sys.put(env, t, k, v(k + 1)).done;
        oracle.insert(k, v(k + 1));
    }
    t
}

fn oracle_range(
    oracle: &BTreeMap<u32, ValueDesc>,
    lo: u32,
    hi: u32,
) -> Vec<(u32, ValueDesc)> {
    oracle.range(lo..hi).map(|(&k, &val)| (k, val)).collect()
}

#[test]
fn cross_shard_cursor_matches_oracle_with_bounds() {
    for kind in KINDS {
        for policy in POLICIES {
            let (mut sys, mut env) = sharded(kind, 4, policy);
            let tag = label(kind, 4, policy);
            let mut oracle = BTreeMap::new();
            let t = populate(&mut *sys, &mut env, &mut oracle);
            // bounds straddling two shard boundaries (range policy)
            let (lo, hi) = (10_000u32, 30_000u32);
            let mut it = sys.iter(&mut env, t, IterOptions::range(lo, hi));
            let t1 = it.seek_to_first(&mut env, t);
            let (got, _) = collect_fwd(&mut *it, &mut env, t1, usize::MAX);
            assert_eq!(got, oracle_range(&oracle, lo, hi), "{tag}: bounded scan");
        }
    }
}

#[test]
fn cross_shard_reverse_and_direction_switch() {
    for kind in KINDS {
        for policy in POLICIES {
            let (mut sys, mut env) = sharded(kind, 4, policy);
            let tag = label(kind, 4, policy);
            let mut oracle = BTreeMap::new();
            let t = populate(&mut *sys, &mut env, &mut oracle);

            // reverse cursor: Seek + N x Next walks descending
            let mut rit = sys.iter(
                &mut env,
                t,
                IterOptions::range(5_000, 45_000).backward(),
            );
            let mut tr = rit.seek_to_first(&mut env, t);
            let mut got_rev = Vec::new();
            for _ in 0..50 {
                let Some(e) = rit.entry() else { break };
                got_rev.push((e.key, e.val));
                tr = rit.next(&mut env, tr);
            }
            let mut want_rev = oracle_range(&oracle, 5_000, 45_000);
            want_rev.reverse();
            want_rev.truncate(50);
            assert_eq!(got_rev, want_rev, "{tag}: reverse walk");

            // direction switch mid-stream: next, next, prev crosses
            // back over the same entries (shard-boundary safe)
            let mut it = sys.iter(&mut env, t, IterOptions::default());
            let mut tt = it.seek(&mut env, t, 12_400);
            let first = it.entry().expect("positioned");
            tt = it.next(&mut env, tt);
            let second = it.entry().expect("next valid");
            assert!(second.key > first.key, "{tag}: ascending");
            tt = it.prev(&mut env, tt);
            assert_eq!(
                it.entry().map(|e| e.key),
                Some(first.key),
                "{tag}: prev returns to the prior entry"
            );
            // seek_for_prev floors onto an existing key
            let probe = 25_001u32;
            let want_floor = oracle.range(..=probe).next_back().map(|(&k, _)| k);
            tt = it.seek_for_prev(&mut env, tt, probe);
            assert_eq!(
                it.entry().map(|e| e.key),
                want_floor,
                "{tag}: seek_for_prev floor"
            );
            let _ = tt;
        }
    }
}

#[test]
fn snapshot_horizon_is_coherent_under_concurrent_puts() {
    for kind in KINDS {
        for policy in POLICIES {
            let (mut sys, mut env) = sharded(kind, 4, policy);
            let tag = label(kind, 4, policy);
            let mut oracle = BTreeMap::new();
            let t = populate(&mut *sys, &mut env, &mut oracle);
            let snap = sys.snapshot(&mut env, t);
            // concurrent writes touch EVERY shard after the pin; a torn
            // horizon would leak some shard's later writes into the view
            let mut t2 = t;
            for k in (3..KEY_SPACE).step_by(17) {
                t2 = sys.put(&mut env, t2, k, v(999_000 + k)).done;
            }
            for k in (0..KEY_SPACE).step_by(123) {
                t2 = sys.delete(&mut env, t2, k).done;
            }
            t2 = sys.flush(&mut env, t2);
            let mut it = sys.iter(&mut env, t2, IterOptions::new().at(&snap));
            let t3 = it.seek_to_first(&mut env, t2);
            let (got, _) = collect_fwd(&mut *it, &mut env, t3, usize::MAX);
            let want: Vec<(u32, ValueDesc)> =
                oracle.iter().map(|(&k, &val)| (k, val)).collect();
            assert_eq!(got, want, "{tag}: snapshot horizon not coherent");
        }
    }
}

// ---------------------------------------------------------------------
// Read-amp: idle shards must not double-charge
// ---------------------------------------------------------------------

#[test]
fn idle_shards_charge_no_read_amp() {
    // all data lives inside shard 0's range, so the 4-shard store's
    // child 0 receives the identical op stream as the 1-shard store's
    // only child. A bounded scan inside that range must then produce
    // IDENTICAL ScanAmp — any extra blocks or nexts would be the
    // double-charge bug from idle shards whose cursors never yield.
    let kind = SystemKind::RocksDb { slowdown: true };
    let mut amps: Vec<ScanAmp> = Vec::new();
    for n in [1usize, 4] {
        let (mut sys, mut env) = sharded(kind, n, ShardPolicy::Range);
        let mut t = 0;
        for k in 0..2_000u32 {
            // keys < KEY_SPACE/4 = shard 0's range in the 4-shard split
            t = sys.put(&mut env, t, k, v(k)).done;
        }
        t = sys.flush(&mut env, t);
        let mut it = sys.iter(&mut env, t, IterOptions::range(100, 1_500));
        let mut tt = it.seek_to_first(&mut env, t);
        let mut steps = 0u64;
        while it.valid() && steps < 1_000 {
            tt = it.next(&mut env, tt);
            steps += 1;
        }
        drop(it);
        let _ = tt;
        amps.push(sys.scan_amp());
    }
    assert_eq!(
        amps[0], amps[1],
        "idle shards inflated read amplification: 1-shard {:?} vs 4-shard {:?}",
        amps[0], amps[1]
    );
    assert!(amps[0].nexts >= 1_000, "scan actually ran: {:?}", amps[0]);
    assert!(amps[0].main_blocks > 0, "scan touched SST blocks");
}

// ---------------------------------------------------------------------
// Bit-compatibility: N=1 range == unsharded
// ---------------------------------------------------------------------

#[test]
fn n1_range_sharding_is_bit_compatible_with_unsharded_fillrandom() {
    for kind in KINDS {
        let cfg = BenchConfig {
            duration: 2 * NS_PER_SEC,
            key_space: KEY_SPACE,
            ..Default::default()
        };
        let spec = WorkloadSpec::from_bench("A/fillrandom", &cfg)
            .with_clients(vec![ClientConfig::writer()]);

        let mut flat = EngineBuilder::new(kind)
            .opts(LsmOptions::small_for_test())
            .build();
        let mut env_a = SimEnv::new(7, SsdConfig::default());
        let (ra, trace_a) =
            workload::run_spec_traced(&mut *flat, &mut env_a, &spec, true);

        let (mut shd, mut env_b) = {
            let sys = EngineBuilder::new(kind)
                .opts(LsmOptions::small_for_test())
                .sharded(1, ShardPolicy::Range)
                .shard_key_space(KEY_SPACE)
                .build();
            (sys, SimEnv::new(7, SsdConfig::default()))
        };
        let (rb, trace_b) =
            workload::run_spec_traced(&mut *shd, &mut env_b, &spec, true);

        assert_eq!(
            trace_a,
            trace_b,
            "{}: N=1 range-sharded op trace diverges from unsharded",
            kind.label()
        );
        assert_eq!(ra.writes.total, rb.writes.total, "{}", kind.label());
        assert_eq!(ra.stop_events, rb.stop_events, "{}", kind.label());
        assert_eq!(ra.redirected_writes, rb.redirected_writes, "{}", kind.label());
        assert_eq!(ra.write_lat.p99_us, rb.write_lat.p99_us, "{}", kind.label());
        assert_eq!(ra.stopped_s, rb.stopped_s, "{}", kind.label());
    }
}

// ---------------------------------------------------------------------
// Durable lifecycle
// ---------------------------------------------------------------------

/// Per-key acked history + flush-barrier cut (the recovery_conformance
/// oracle, specialized for the sharded suites).
#[derive(Default)]
struct Oracle {
    history: HashMap<Key, Vec<Option<ValueDesc>>>,
    barrier: HashMap<Key, usize>,
}

impl Oracle {
    fn record(&mut self, key: Key, val: Option<ValueDesc>) {
        self.history.entry(key).or_default().push(val);
    }

    fn set_barrier(&mut self) {
        for (k, h) in &self.history {
            self.barrier.insert(*k, h.len() - 1);
        }
    }

    fn check(&self, key: Key, got: Option<ValueDesc>, label: &str) {
        let Some(h) = self.history.get(&key) else {
            assert_eq!(got, None, "{label}: key {key} never written");
            return;
        };
        let allowed: Vec<Option<ValueDesc>> = match self.barrier.get(&key) {
            Some(&b) => h[b..].to_vec(),
            None => {
                let mut a = h.clone();
                a.push(None);
                a
            }
        };
        assert!(
            allowed.contains(&got),
            "{label}: key {key} recovered {got:?}, allowed {allowed:?}"
        );
    }
}

fn run_crash_workload(
    sys: &mut dyn KvEngine,
    env: &mut SimEnv,
    oracle: &mut Oracle,
    n1: u32,
    n2: u32,
) -> Nanos {
    let mut t = 0;
    for i in 0..n1 {
        let k = (i * 37) % KEY_SPACE;
        t = sys.put(env, t, k, v(i)).done;
        oracle.record(k, Some(v(i)));
    }
    t = sys.flush(env, t);
    oracle.set_barrier();
    for i in 0..n2 {
        let k = (i * 53) % KEY_SPACE;
        if i % 29 == 7 {
            t = sys.delete(env, t, k).done;
            oracle.record(k, None);
        } else {
            t = sys.put(env, t, k, v(10_000 + i)).done;
            oracle.record(k, Some(v(10_000 + i)));
        }
    }
    t
}

#[test]
fn clean_close_reopens_with_zero_wal_records_per_shard() {
    for kind in KINDS {
        for policy in POLICIES {
            let (mut sys, mut env) = sharded(kind, 4, policy);
            let tag = label(kind, 4, policy);
            let mut t = 0;
            for i in 0..1_200u32 {
                t = sys.put(&mut env, t, (i * 41) % KEY_SPACE, v(i)).done;
            }
            let image = sys.close(&mut env, t).unwrap();
            assert!(image.clean, "{tag}");
            assert_eq!(
                image.wal_records(),
                0,
                "{tag}: clean close must leave no WAL to replay"
            );
            let shard = image.shard.as_ref().expect("sharded image");
            assert_eq!(shard.children.len(), 4, "{tag}");
            let (mut sys2, t2) = EngineBuilder::open(&mut env, t, image).expect("recovery failed");
            let h = sys2.health();
            assert_eq!(h.recovered_wal_records, 0, "{tag}: zero-replay reopen");
            // spot-check data
            let mut tt = t2;
            for i in (0..1_200u32).step_by(97) {
                let latest = (0..1_200u32)
                    .filter(|j| (j * 41) % KEY_SPACE == (i * 41) % KEY_SPACE)
                    .max()
                    .unwrap();
                let (got, nt) = sys2.get(&mut env, tt, (i * 41) % KEY_SPACE);
                tt = nt;
                assert_eq!(got, Some(v(latest)), "{tag}: key of op {i}");
            }
        }
    }
}

#[test]
fn crash_recovery_is_prefix_consistent_across_shards() {
    for kind in KINDS {
        for policy in POLICIES {
            for (n1, n2) in [(400u32, 300u32), (900, 50)] {
                let (mut sys, mut env) = sharded(kind, 4, policy);
                let tag = format!("{} ({n1}+{n2})", label(kind, 4, policy));
                let mut oracle = Oracle::default();
                let t = run_crash_workload(&mut *sys, &mut env, &mut oracle, n1, n2);
                let image = sys.crash(&mut env, t);
                assert!(!image.clean);
                let (mut sys2, t2) = EngineBuilder::open(&mut env, t, image).expect("recovery failed");
                let mut tt = t2;
                for probe in 0..KEY_SPACE {
                    if probe % 37 != 0 && probe % 53 != 0 {
                        continue;
                    }
                    let (got, nt) = sys2.get(&mut env, tt, probe);
                    tt = nt;
                    oracle.check(probe, got, &tag);
                }
            }
        }
    }
}

#[test]
fn double_crash_keeps_per_shard_wal_streams_consistent() {
    // crash, recover, write more, crash again: the second life's WAL
    // streams restart per shard, so no shard can treat its new log's
    // page-cached tail as durable
    let (mut sys, mut env) = sharded(
        SystemKind::Kvaccel { scheme: RollbackScheme::Disabled },
        4,
        ShardPolicy::Range,
    );
    let mut oracle = Oracle::default();
    let t = run_crash_workload(&mut *sys, &mut env, &mut oracle, 600, 200);
    let image = sys.crash(&mut env, t);
    let (mut sys2, t2) = EngineBuilder::open(&mut env, t, image).expect("recovery failed");
    // treat everything visible after the first recovery as the new
    // acked history baseline
    let mut oracle2 = Oracle::default();
    let mut tt = t2;
    for probe in (0..KEY_SPACE).step_by(37) {
        let (got, nt) = sys2.get(&mut env, tt, probe);
        tt = nt;
        oracle2.record(probe, got);
    }
    tt = sys2.flush(&mut env, tt);
    oracle2.set_barrier();
    for i in 0..300u32 {
        let k = (i * 37) % KEY_SPACE;
        tt = sys2.put(&mut env, tt, k, v(77_000 + i)).done;
        oracle2.record(k, Some(v(77_000 + i)));
    }
    let image2 = sys2.crash(&mut env, tt);
    let (mut sys3, t3) = EngineBuilder::open(&mut env, tt, image2).expect("recovery failed");
    let mut t4 = t3;
    for probe in (0..KEY_SPACE).step_by(37) {
        let (got, nt) = sys3.get(&mut env, t4, probe);
        t4 = nt;
        oracle2.check(probe, got, "double crash");
    }
}

#[test]
fn crash_mid_rebalance_recovers_a_consistent_grant_table() {
    // build the concrete ShardedDb so the arbiter fault-injection hook
    // is reachable
    let spec = {
        let mut s = ShardSpec::new(4, ShardPolicy::Range);
        s.key_space = KEY_SPACE;
        s
    };
    let mut db = ShardedDb::new(
        spec,
        SystemKind::Kvaccel { scheme: RollbackScheme::Disabled },
        LsmOptions::small_for_test(),
        MergeEngine::rust(),
        BloomBuilder::rust(),
        KvaccelConfig::default(),
        kvaccel::baselines::AdocConfig::default(),
    );
    let mut env = SimEnv::new(9, SsdConfig::default());
    let mut oracle = Oracle::default();
    let t = run_crash_workload(&mut db, &mut env, &mut oracle, 800, 100);
    let total = db.arbiter().config().total_occupancy;
    // wedge a transfer open: donor revoked, credit not yet applied —
    // the torn window a crash can land in
    assert!(
        db.arbiter_mut().begin_transfer(t, 1, 0, 0.1),
        "transfer must start"
    );
    let torn_sum: f64 = db.arbiter().grants().iter().sum();
    assert!(torn_sum < total - 1e-9, "grant table is torn mid-transfer");
    let image = Box::new(db).crash(&mut env, t);
    {
        let shard = image.shard.as_ref().expect("sharded image");
        assert!(shard.pending.is_some(), "pending transfer recorded durably");
    }
    let (mut sys2, t2) = EngineBuilder::open(&mut env, t, image).expect("recovery failed");
    let sh = sys2.sharded().expect("reopened as sharded");
    let sum: f64 = sh.arbiter().grants().iter().sum();
    assert!(
        (sum - total).abs() < 1e-9,
        "recovered grant table must sum to the full budget: {sum} vs {total}"
    );
    assert!(sh.arbiter().pending().is_none(), "transfer resolved");
    assert_eq!(sh.arbiter().stats.recovered_transfers, 1);
    let min = sh.arbiter().config().min_grant;
    for (i, &g) in sh.arbiter().grants().iter().enumerate() {
        assert!(g >= min - 1e-9, "shard {i} grant {g} below floor {min}");
    }
    // and the data survived like any other crash
    let mut tt = t2;
    for probe in (0..KEY_SPACE).step_by(37) {
        let (got, nt) = sys2.get(&mut env, tt, probe);
        tt = nt;
        oracle.check(probe, got, "crash mid-rebalance");
    }
}

// ---------------------------------------------------------------------
// Scaling smoke (the shard-scale experiment's acceptance shape)
// ---------------------------------------------------------------------

#[test]
fn kvaccel_shards_share_the_device_without_anomalies() {
    let cfg = BenchConfig {
        duration: 2 * NS_PER_SEC,
        key_space: KEY_SPACE,
        ..Default::default()
    };
    let mut totals = Vec::new();
    for n in [1usize, 4] {
        let (mut sys, mut env) = sharded(
            SystemKind::Kvaccel { scheme: RollbackScheme::Disabled },
            n,
            ShardPolicy::Range,
        );
        let spec = workload::preset_spec(
            "A",
            &cfg,
            8,
            workload::LoopMode::Closed { think: 0 },
            workload::KeyDist::Uniform,
        )
        .unwrap();
        let r = workload::run_spec(&mut *sys, &mut env, &spec);
        assert_eq!(
            sys.db_stats().stall_anomalies,
            0,
            "{n} shards: stall anomalies"
        );
        assert!(r.writes.total > 500, "{n} shards: writes {}", r.writes.total);
        totals.push(r.writes.total as f64);
    }
    // sharding the ingest must not cost aggregate throughput; typically
    // it gains (less per-shard stall pressure)
    assert!(
        totals[1] >= totals[0] * 0.9,
        "4-shard throughput regressed vs 1 shard: {} vs {}",
        totals[1],
        totals[0]
    );
}
