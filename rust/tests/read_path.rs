//! Read-path acceleration conformance: the engine-wide block cache and
//! the block-compression cost model must never change *what* a read
//! returns — only what it costs. One suite run against every `KvEngine`
//! implementation, in every cache x codec configuration.
//!
//! Covers: value identity across configurations, determinism of traced
//! runs with the cache and codec enabled, cache truthfulness across
//! flush/compaction/rollback invalidation, scan-warms-get coupling
//! through the one shared cache instance (including a sharded store),
//! and the measured bloom false-positive rate against the configured
//! geometry.

use std::collections::BTreeMap;

use kvaccel::engine::{EngineBuilder, EngineStats, KvEngine};
use kvaccel::env::SimEnv;
use kvaccel::kvaccel::RollbackScheme;
use kvaccel::lsm::{Compression, LsmOptions, ValueDesc};
use kvaccel::shard::ShardPolicy;
use kvaccel::sim::{Nanos, SimRng};
use kvaccel::ssd::SsdConfig;
use kvaccel::workload::{self, BenchConfig, KeyDist, LoopMode};

const ENGINES: [&str; 6] = [
    "rocksdb",
    "rocksdb-nosd",
    "adoc",
    "kvaccel",
    "kvaccel-eager",
    "kvaccel-lazy",
];

fn build_with(name: &str, opts: LsmOptions) -> (Box<dyn KvEngine>, SimEnv) {
    let sys = match name {
        "rocksdb" => EngineBuilder::rocksdb(true).opts(opts).build(),
        "rocksdb-nosd" => EngineBuilder::rocksdb(false).opts(opts).build(),
        "adoc" => EngineBuilder::adoc().opts(opts).build(),
        "kvaccel" => EngineBuilder::kvaccel().opts(opts).build(),
        "kvaccel-eager" => {
            EngineBuilder::kvaccel_scheme(RollbackScheme::Eager).opts(opts).build()
        }
        "kvaccel-lazy" => {
            EngineBuilder::kvaccel_scheme(RollbackScheme::Lazy).opts(opts).build()
        }
        other => panic!("unknown engine {other}"),
    };
    (sys, SimEnv::new(33, SsdConfig::default()))
}

/// The four read-path configurations: cache {off, on} x codec {none,
/// lz-like:50}, over the small-store test options.
fn configs() -> Vec<(String, LsmOptions)> {
    let mut out = Vec::new();
    for cache in [0usize, 128] {
        for codec in [Compression::None, Compression::LzLike { ratio_pct: 50 }] {
            let label = format!(
                "cache={cache} codec={}",
                if codec.is_none() { "none" } else { "lz-like:50" }
            );
            out.push((
                label,
                LsmOptions::small_for_test()
                    .with_cache_blocks(cache)
                    .with_compression(codec),
            ));
        }
    }
    out
}

fn v(tag: u32) -> ValueDesc {
    ValueDesc::new(tag, 4096)
}

/// Tentpole contract: the cache and the codec are cost models, not data
/// paths — the same op stream must read back identically in every
/// configuration, on every engine, including gets issued mid-churn
/// while flushes/compactions (and on KVACCEL, rollbacks) invalidate
/// cached blocks underneath.
#[test]
fn values_identical_across_cache_and_codec_configs() {
    for name in ENGINES {
        let mut states: Vec<(String, Vec<(u32, ValueDesc)>)> = Vec::new();
        for (label, opts) in configs() {
            let (mut sys, mut env) = build_with(name, opts);
            let mut rng = SimRng::new(4242);
            let mut oracle: BTreeMap<u32, Option<ValueDesc>> = BTreeMap::new();
            let mut t: Nanos = 0;
            for op in 0..500u32 {
                match rng.gen_range_u32(10) {
                    0..=5 => {
                        let k = rng.gen_range_u32(400);
                        t = sys.put(&mut env, t, k, v(op)).done;
                        oracle.insert(k, Some(v(op)));
                    }
                    6 => {
                        let k = rng.gen_range_u32(400);
                        t = sys.delete(&mut env, t, k).done;
                        oracle.insert(k, None);
                    }
                    7..=8 => {
                        // mid-churn read: cached blocks must stay truthful
                        // while background work replaces SSTs
                        let k = rng.gen_range_u32(400);
                        let (got, nt) = sys.get(&mut env, t, k);
                        t = nt;
                        let want = oracle.get(&k).copied().flatten();
                        assert_eq!(got, want, "{name} [{label}]: mid-churn get({k})");
                    }
                    _ => {
                        t = sys.flush(&mut env, t);
                    }
                }
            }
            t = sys.finish(&mut env, t).unwrap();
            for k in (0..400u32).step_by(7) {
                let (got, nt) = sys.get(&mut env, t, k);
                t = nt;
                let want = oracle.get(&k).copied().flatten();
                assert_eq!(got, want, "{name} [{label}]: post-finish get({k})");
            }
            let (all, _) = sys.scan(&mut env, t, 0, 10_000);
            let got: Vec<(u32, ValueDesc)> =
                all.iter().map(|e| (e.key, e.val)).collect();
            let want: Vec<(u32, ValueDesc)> = oracle
                .iter()
                .filter_map(|(&k, &val)| val.map(|val| (k, val)))
                .collect();
            assert_eq!(got, want, "{name} [{label}]: final state diverges");
            states.push((label, got));
        }
        for pair in states.windows(2) {
            assert_eq!(
                pair[0].1, pair[1].1,
                "{name}: [{}] and [{}] diverge",
                pair[0].0, pair[1].0
            );
        }
    }
}

/// The write path never consults the block cache, so resizing it must
/// not move a single write completion time — the conformance anchor for
/// "cache-off traces are bit-identical to the pre-cache engine".
#[test]
fn write_timing_is_independent_of_cache_capacity() {
    for name in ENGINES {
        let (mut a, mut env_a) =
            build_with(name, LsmOptions::small_for_test().with_cache_blocks(0));
        let (mut b, mut env_b) =
            build_with(name, LsmOptions::small_for_test().with_cache_blocks(4096));
        let (mut ta, mut tb) = (0, 0);
        for k in 0..600u32 {
            ta = a.put(&mut env_a, ta, k % 251, v(k)).done;
            tb = b.put(&mut env_b, tb, k % 251, v(k)).done;
            assert_eq!(ta, tb, "{name}: put #{k} timing shifted with cache size");
        }
    }
}

/// A traced workload with the cache and compression enabled replays
/// bit-identically for the same seed: hit/miss sequences (and therefore
/// every op latency) are deterministic functions of the op stream.
#[test]
fn traced_runs_are_deterministic_with_cache_and_compression() {
    let cfg = BenchConfig {
        seed: 7,
        key_space: 4096,
        value_size: 1024,
        ..Default::default()
    };
    let opts = LsmOptions::small_for_test()
        .with_cache_blocks(256)
        .with_compression(Compression::LzLike { ratio_pct: 50 });
    for name in ["rocksdb", "adoc", "kvaccel-lazy"] {
        let mut traces = Vec::new();
        for _ in 0..2 {
            let (mut sys, mut env) = build_with(name, opts.clone());
            let t0 = workload::preload(&mut *sys, &mut env, &cfg, 256 * 1024).unwrap();
            let mut spec = workload::preset_spec(
                "ycsb-b",
                &cfg,
                2,
                LoopMode::Closed { think: 0 },
                KeyDist::Uniform,
            )
            .unwrap();
            spec.start_at = t0;
            spec.stop_after_ops = Some(300);
            let (_, trace) = workload::run_spec_traced(&mut *sys, &mut env, &spec, true);
            assert!(!trace.is_empty(), "{name}: traced run produced no ops");
            traces.push(trace);
        }
        assert_eq!(traces[0], traces[1], "{name}: cached traced run not deterministic");
    }
}

/// KVACCEL-specific: reads served off the device write buffer go through
/// the dev namespace of the same cache; entries must stay truthful while
/// keys get superseded and must not survive the rollback that drains the
/// buffer back into the host LSM.
#[test]
fn kvaccel_dev_reads_stay_correct_with_cache_through_rollback() {
    for scheme in ["kvaccel", "kvaccel-eager", "kvaccel-lazy"] {
        let (mut sys, mut env) =
            build_with(scheme, LsmOptions::small_for_test().with_cache_blocks(256));
        let mut oracle: BTreeMap<u32, ValueDesc> = BTreeMap::new();
        let mut t = 0;
        // sustained load over a small store: the detector redirects a
        // tail of these into the device write buffer
        for i in 0..4000u32 {
            let k = i % 1000;
            t = sys.put(&mut env, t, k, v(i)).done;
            oracle.insert(k, v(i));
        }
        assert!(sys.redirected_writes() > 0, "{scheme}: no writes redirected");
        // two read rounds: the first warms the dev-read cache, the
        // second is served from it — both must match the oracle
        for round in 0..2 {
            for k in 0..1000u32 {
                let (got, nt) = sys.get(&mut env, t, k);
                t = nt;
                assert_eq!(
                    got,
                    oracle.get(&k).copied(),
                    "{scheme}: round {round} get({k})"
                );
            }
        }
        assert!(sys.cache_stats().hits > 0, "{scheme}: warm round never hit");
        // finish = final rollback: the buffer merges back into the host
        // LSM and the dev-namespace cache entries are purged — reads must
        // still be correct afterwards
        t = sys.finish(&mut env, t).unwrap();
        for k in 0..1000u32 {
            let (got, nt) = sys.get(&mut env, t, k);
            t = nt;
            assert_eq!(got, oracle.get(&k).copied(), "{scheme}: post-rollback get({k})");
        }
    }
}

/// Satellite coupling check: cursors and `get()` share the one
/// engine-wide cache instance, so a range scan warms subsequent point
/// reads over the same keys.
#[test]
fn scans_warm_the_point_read_cache() {
    for name in ENGINES {
        let (mut sys, mut env) =
            build_with(name, LsmOptions::small_for_test().with_cache_blocks(128));
        let mut t = 0;
        for k in 0..800u32 {
            t = sys.put(&mut env, t, k, ValueDesc::new(k, 512)).done;
        }
        t = sys.finish(&mut env, t).unwrap();
        let c0 = sys.cache_stats();
        let (all, nt) = sys.scan(&mut env, t, 0, 2000);
        t = nt;
        assert_eq!(all.len(), 800, "{name}: scan result short");
        let c1 = sys.cache_stats();
        assert!(
            c1.misses > c0.misses,
            "{name}: a cold scan should miss its way through the store"
        );
        for k in 0..300u32 {
            let (got, nt) = sys.get(&mut env, t, k);
            t = nt;
            assert_eq!(got, Some(ValueDesc::new(k, 512)), "{name}: get({k})");
        }
        let c2 = sys.cache_stats();
        let hits = c2.hits - c1.hits;
        let misses = c2.misses - c1.misses;
        assert!(
            hits > 0 && hits >= misses * 3,
            "{name}: scan didn't warm point reads (hits {hits}, misses {misses})"
        );
    }
}

/// A sharded store holds ONE cache instance across all shards (the
/// engine-wide tentpole), not one per shard: capacity reads back
/// unsplit, and a cross-shard scan warms point gets on every shard.
#[test]
fn sharded_store_shares_one_engine_wide_cache() {
    for policy in [ShardPolicy::Range, ShardPolicy::Hash] {
        let mut env = SimEnv::new(33, SsdConfig::default());
        let mut sys = EngineBuilder::lsm()
            .opts(LsmOptions::small_for_test().with_cache_blocks(128))
            .sharded(4, policy)
            .shard_key_space(1024)
            .build();
        let mut t = 0;
        for k in 0..1024u32 {
            t = sys.put(&mut env, t, k, ValueDesc::new(k, 512)).done;
        }
        t = sys.finish(&mut env, t).unwrap();
        let c = sys.cache_stats();
        assert_eq!(
            c.capacity_blocks,
            128,
            "{}: children must share one instance, not get one each",
            policy.label()
        );
        let (all, nt) = sys.scan(&mut env, t, 0, 4096);
        t = nt;
        assert_eq!(all.len(), 1024, "{}: scan short", policy.label());
        let c1 = sys.cache_stats();
        for k in (0..1024u32).step_by(4) {
            let (got, nt) = sys.get(&mut env, t, k);
            t = nt;
            assert_eq!(got, Some(ValueDesc::new(k, 512)), "{}", policy.label());
        }
        let c2 = sys.cache_stats();
        let hits = c2.hits - c1.hits;
        let misses = c2.misses - c1.misses;
        assert!(
            hits > 0 && hits >= misses * 3,
            "{}: cross-shard scan didn't warm gets (hits {hits}, misses {misses})",
            policy.label()
        );
    }
}

/// `--cache-blocks 0` means *off*: the hot paths skip the probe
/// entirely, so no counter moves and nothing is retained.
#[test]
fn zero_capacity_cache_is_fully_disabled() {
    for name in ["rocksdb", "kvaccel"] {
        let (mut sys, mut env) =
            build_with(name, LsmOptions::small_for_test().with_cache_blocks(0));
        let mut t = 0;
        for k in 0..600u32 {
            t = sys.put(&mut env, t, k, ValueDesc::new(k, 512)).done;
        }
        t = sys.finish(&mut env, t).unwrap();
        let (_, nt) = sys.scan(&mut env, t, 0, 1000);
        t = nt;
        for k in 0..600u32 {
            let (_, nt) = sys.get(&mut env, t, k);
            t = nt;
        }
        let c = sys.cache_stats();
        assert_eq!(
            (c.hits, c.misses, c.cached_blocks, c.capacity_blocks),
            (0, 0, 0, 0),
            "{name}: disabled cache must stay untouched"
        );
    }
}

/// The measured bloom false-positive rate stays within 2x the rate the
/// configured geometry (bits/key, probe count) predicts.
#[test]
fn measured_bloom_fpr_within_2x_of_configured() {
    let (mut sys, mut env) =
        build_with("rocksdb", LsmOptions::small_for_test().with_cache_blocks(0));
    let mut t = 0;
    // even keys present, odd keys absent-but-in-range so absent-key
    // gets land inside SST key ranges and actually consult the filters
    for k in 0..3000u32 {
        t = sys.put(&mut env, t, k * 2, ValueDesc::new(k, 512)).done;
    }
    t = sys.finish(&mut env, t).unwrap();
    for k in 0..3000u32 {
        let (got, nt) = sys.get(&mut env, t, k * 2 + 1);
        t = nt;
        assert_eq!(got, None, "odd key {} must be absent", k * 2 + 1);
    }
    let d = sys.db_stats();
    assert!(
        d.bloom_negative_probes > 2000,
        "too few negative probes to measure: {}",
        d.bloom_negative_probes
    );
    let o = LsmOptions::default();
    // standard bloom approximation: (1 - e^(-k/b))^k for k probes over
    // b bits/key; bloom_bits_for only ever rounds capacity *up*
    let configured = (1.0
        - (-(o.bloom_probes as f64) / o.bloom_bits_per_key as f64).exp())
    .powi(o.bloom_probes as i32);
    let measured = d.bloom_fpr();
    assert!(
        measured <= configured * 2.0,
        "measured fpr {measured:.5} exceeds 2x configured {configured:.5}"
    );
    let _ = t;
}

/// Compression is a real trade on the write path too: a 50% codec must
/// flush materially fewer device bytes than the identity codec.
#[test]
fn compression_shrinks_flushed_bytes() {
    let mut flushed = Vec::new();
    for codec in [Compression::None, Compression::LzLike { ratio_pct: 50 }] {
        let (mut sys, mut env) = build_with(
            "rocksdb",
            LsmOptions::small_for_test().with_cache_blocks(0).with_compression(codec),
        );
        let mut t = 0;
        for k in 0..800u32 {
            t = sys.put(&mut env, t, k, ValueDesc::new(k, 1024)).done;
        }
        sys.finish(&mut env, t).unwrap();
        assert!(sys.db_stats().flush_count > 0, "store never flushed");
        flushed.push(sys.db_stats().bytes_flushed);
    }
    let (plain, packed) = (flushed[0], flushed[1]);
    assert!(
        packed < plain,
        "50% codec must flush fewer bytes ({packed} vs {plain})"
    );
    assert!(
        packed * 100 >= plain * 40,
        "50% codec shrank flushes implausibly far ({packed} vs {plain})"
    );
}
