//! Scheduler conformance: the event-driven workload layer must be
//! deterministic (same seed + config => identical op trace and final
//! stats, for every engine kind), must preserve the pre-refactor
//! db_bench semantics (fillrandom op stream bit-compat, write:read
//! ratios within 1%), and must expose the open-loop overload pathology
//! (growing queueing delay on the plain LSM, bounded tail on KVACCEL).

use kvaccel::engine::{EngineBuilder, EngineStats, KvEngine};
use kvaccel::env::SimEnv;
use kvaccel::kvaccel::RollbackScheme;
use kvaccel::lsm::LsmOptions;
use kvaccel::sim::{Nanos, NS_PER_SEC};
use kvaccel::ssd::SsdConfig;
use kvaccel::workload::{
    fillrandom, preset_spec, readwhilewriting, run_spec, run_spec_traced, BenchConfig,
    ClientConfig, KeyDist, KeyGen, LoopMode, OpMix, ValueSizeDist, WorkloadSpec,
};

const ENGINES: [&str; 6] = [
    "rocksdb",
    "rocksdb-nosd",
    "adoc",
    "kvaccel",
    "kvaccel-eager",
    "kvaccel-lazy",
];

fn build(name: &str) -> (Box<dyn KvEngine>, SimEnv) {
    let opts = LsmOptions::small_for_test();
    let sys = match name {
        "rocksdb" => EngineBuilder::rocksdb(true).opts(opts).build(),
        "rocksdb-nosd" => EngineBuilder::rocksdb(false).opts(opts).build(),
        "adoc" => EngineBuilder::adoc().opts(opts).build(),
        "kvaccel" => EngineBuilder::kvaccel().opts(opts).build(),
        "kvaccel-eager" => {
            EngineBuilder::kvaccel_scheme(RollbackScheme::Eager).opts(opts).build()
        }
        "kvaccel-lazy" => {
            EngineBuilder::kvaccel_scheme(RollbackScheme::Lazy).opts(opts).build()
        }
        other => panic!("unknown engine {other}"),
    };
    (sys, SimEnv::new(21, SsdConfig::default()))
}

/// A spec exercising every scheduler feature at once: closed-loop
/// writer, Poisson mixed client with a zipfian stream, fixed-rate
/// open-loop reader.
fn mixed_spec(duration: Nanos) -> WorkloadSpec {
    WorkloadSpec {
        name: "conformance-mix".into(),
        clients: vec![
            ClientConfig::writer(),
            ClientConfig {
                mix: OpMix { put: 3, get: 1, delete: 1, scan: 1, batch: 0 },
                mode: LoopMode::OpenPoisson { ops_per_sec: 2_000.0 },
                dist: KeyDist::Zipfian { theta: 0.9 },
                scan_len: 8,
                seed_tag: 17,
                ..ClientConfig::default()
            },
            ClientConfig::reader()
                .with_mode(LoopMode::OpenFixed { ops_per_sec: 1_000.0 })
                .with_seed_tag(99),
        ],
        duration,
        start_at: 0,
        key_space: 20_000,
        value_size: 4096,
        value_dist: ValueSizeDist::Fixed(4096),
        seed: 7,
        stop_after_ops: None,
        qos: None,
    }
}

#[test]
fn scheduler_deterministic_and_stall_clean_for_all_engines() {
    let spec = mixed_spec(NS_PER_SEC / 2);
    for name in ENGINES {
        let (mut s1, mut env1) = build(name);
        let (r1, t1) = run_spec_traced(&mut *s1, &mut env1, &spec, true);
        let (mut s2, mut env2) = build(name);
        let (r2, t2) = run_spec_traced(&mut *s2, &mut env2, &spec, true);

        assert_eq!(t1.len(), t2.len(), "{name}: trace lengths differ");
        assert_eq!(t1, t2, "{name}: op traces diverge");
        assert_eq!(r1.writes.total, r2.writes.total, "{name}");
        assert_eq!(r1.reads.total, r2.reads.total, "{name}");
        assert_eq!(r1.read_hits, r2.read_hits, "{name}");
        assert_eq!(r1.write_lat.p99_us, r2.write_lat.p99_us, "{name}");
        assert_eq!(r1.queue_delay.p99_us, r2.queue_delay.p99_us, "{name}");
        assert_eq!(r1.slowdown_events, r2.slowdown_events, "{name}");
        assert_eq!(
            s1.db_stats().stall_anomalies,
            0,
            "{name}: stall anomaly under scheduler load"
        );
        assert_eq!(s2.db_stats().stall_anomalies, 0, "{name}");
        assert!(r1.writes.total > 0 && r1.reads.total > 0, "{name}: degenerate run");
    }
}

#[test]
fn scheduler_deterministic_with_qos_enforced() {
    // the QoS path adds token-bucket reschedules, SLO ticks and backlog
    // shedding to the event stream; all of it must stay a pure function
    // of (spec, seed) on every engine kind
    let spec = mixed_spec(NS_PER_SEC / 2).with_tenants(2, 800.0, Some(20_000_000));
    for name in ENGINES {
        let (mut s1, mut env1) = build(name);
        let (r1, t1) = run_spec_traced(&mut *s1, &mut env1, &spec, true);
        let (mut s2, mut env2) = build(name);
        let (r2, t2) = run_spec_traced(&mut *s2, &mut env2, &spec, true);
        assert_eq!(t1, t2, "{name}: enforced-QoS op traces diverge");
        assert_eq!(r1.writes.total, r2.writes.total, "{name}");
        assert_eq!(r1.queue_delay.p99_us, r2.queue_delay.p99_us, "{name}");
        assert_eq!(r1.tenants.len(), 2, "{name}: missing tenant breakdown");
        for (a, b) in r1.tenants.iter().zip(&r2.tenants) {
            assert_eq!(a.ops, b.ops, "{name}: tenant ops diverge");
            assert_eq!(a.throttled, b.throttled, "{name}: throttle counts diverge");
            assert_eq!(a.shed, b.shed, "{name}: shed counts diverge");
        }
        // the metered run stays live: both tenants make progress
        assert!(r1.tenants.iter().all(|t| t.ops > 0), "{name}: a tenant starved");
    }
}

#[test]
fn fillrandom_preset_matches_prerefactor_op_stream() {
    // the preset must issue the exact op stream of the pre-scheduler
    // single-writer loop: same keys, same values, same timing
    let cfg = BenchConfig {
        duration: NS_PER_SEC,
        key_space: 30_000,
        ..Default::default()
    };
    let spec = WorkloadSpec {
        name: "A/fillrandom".into(),
        clients: vec![ClientConfig::writer()],
        duration: cfg.duration,
        start_at: 0,
        key_space: cfg.key_space,
        value_size: cfg.value_size,
        value_dist: ValueSizeDist::Fixed(cfg.value_size),
        seed: cfg.seed,
        stop_after_ops: None,
        qos: None,
    };
    let (mut s1, mut env1) = build("rocksdb");
    let (_, trace) = run_spec_traced(&mut *s1, &mut env1, &spec, true);

    // hand-rolled pre-refactor reference loop
    let (mut s2, mut env2) = build("rocksdb");
    let mut gen = KeyGen::new(cfg.seed, cfg.key_space, cfg.value_size);
    let mut reference = Vec::new();
    let mut t: Nanos = 0;
    let mut op: u64 = 0;
    while t < cfg.duration {
        let key = gen.random_key();
        let val = gen.value_for(key, op);
        let r = s2.put(&mut env2, t, key, val);
        reference.push((key, t, r.done));
        t = r.done;
        op += 1;
    }
    assert_eq!(trace.len(), reference.len());
    for (got, want) in trace.iter().zip(&reference) {
        assert_eq!((got.key, got.issue, got.done), *want);
    }
}

#[test]
fn readwhilewriting_ratio_within_one_percent() {
    // Paper-default engine options: the reader has ample headroom, so
    // both the pre-refactor interleaving loop and the scheduler's paced
    // read client converge to the configured op ratio. (Under the
    // deliberately tiny test options, a saturated reader caps the read
    // count — in both implementations — which is a different regime.)
    for (w, r) in [(9u64, 1u64), (8, 2)] {
        let cfg = BenchConfig {
            duration: NS_PER_SEC,
            key_space: 50_000,
            ..Default::default()
        };
        let mut s = EngineBuilder::rocksdb(true)
            .opts(LsmOptions::default().with_threads(2))
            .build();
        let mut env = SimEnv::new(21, SsdConfig::default());
        let res = readwhilewriting(&mut *s, &mut env, &cfg, w, r);
        assert!(res.reads.total > 100, "{w}:{r} too few reads: {}", res.reads.total);
        let got = res.writes.total as f64 / res.reads.total as f64;
        let want = w as f64 / r as f64;
        assert!(
            (got - want).abs() / want < 0.01,
            "{w}:{r} ratio drifted by >1%: got {got:.4}, want {want}"
        );
        assert_eq!(res.read_hits + res.read_misses, res.reads.total);
    }
}

#[test]
fn open_loop_overload_grows_lsm_queue_kvaccel_stays_bounded() {
    // measure the LSM's sustainable closed-loop rate, then offer 3x that
    let cfg = BenchConfig {
        duration: 2 * NS_PER_SEC,
        key_space: 100_000,
        ..Default::default()
    };
    let (mut probe, mut env0) = build("rocksdb");
    let closed = fillrandom(&mut *probe, &mut env0, &cfg);
    let sustainable = closed.writes.total as f64 / closed.duration_s;
    assert!(sustainable > 100.0, "probe run degenerate: {sustainable}");
    let rate = sustainable * 3.0;

    let over_cfg = BenchConfig { duration: 3 * NS_PER_SEC, ..cfg };
    let spec = preset_spec(
        "A",
        &over_cfg,
        2,
        LoopMode::OpenFixed { ops_per_sec: rate },
        KeyDist::Uniform,
    )
    .unwrap();

    let (mut lsm, mut env1) = build("rocksdb");
    let rl = run_spec(&mut *lsm, &mut env1, &spec);
    let (mut kva, mut env2) = build("kvaccel");
    let rk = run_spec(&mut *kva, &mut env2, &spec);

    // LSM: arrivals outpace service, so per-second mean queueing delay
    // must climb from the first half of the run to the second
    let series = &rl.queue_delay_series_us;
    assert!(series.len() >= 2, "no queue-delay series: {series:?}");
    let half = series.len() / 2;
    let first: f64 = series[..half].iter().sum::<f64>() / half as f64;
    let second: f64 =
        series[half..].iter().sum::<f64>() / (series.len() - half) as f64;
    assert!(
        second > first * 1.5 && second > 1_000.0,
        "LSM queueing delay not growing under overload: first-half {first:.0} us, second-half {second:.0} us"
    );

    // KVACCEL under the same offered load engages redirection and keeps
    // both the queue and the total-latency tail below the LSM baseline
    assert!(rk.redirected_writes > 0, "KVACCEL never redirected under overload");
    assert_eq!(rk.stop_events, 0, "KVACCEL must not hard-stop");
    assert!(
        rk.queue_delay.p99_us < rl.queue_delay.p99_us,
        "KVACCEL queue p99 {} >= LSM {}",
        rk.queue_delay.p99_us,
        rl.queue_delay.p99_us
    );
    assert!(
        rk.write_lat.p99_us < rl.write_lat.p99_us,
        "KVACCEL total p99 {} >= LSM {}",
        rk.write_lat.p99_us,
        rl.write_lat.p99_us
    );
}

#[test]
fn zipfian_and_latest_clients_run_on_every_engine() {
    for name in ENGINES {
        for dist in [KeyDist::Zipfian { theta: 0.99 }, KeyDist::Latest] {
            let spec = WorkloadSpec {
                name: format!("dist-{dist:?}"),
                clients: vec![
                    ClientConfig::writer().with_dist(dist),
                    ClientConfig {
                        mix: OpMix::put_get(1, 1),
                        dist,
                        seed_tag: 5,
                        ..ClientConfig::default()
                    },
                ],
                duration: NS_PER_SEC / 4,
                start_at: 0,
                key_space: 10_000,
                value_size: 1024,
                value_dist: ValueSizeDist::Fixed(1024),
                seed: 13,
                stop_after_ops: None,
                qos: None,
            };
            let (mut s, mut env) = build(name);
            let r = run_spec(&mut *s, &mut env, &spec);
            assert!(r.writes.total > 50, "{name}/{dist:?}: {}", r.writes.total);
            assert!(r.reads.total > 0, "{name}/{dist:?}");
            // latest-biased reads against a writer that appends should
            // hit much more often than uniform cold reads
            if dist == KeyDist::Latest {
                assert!(
                    r.read_hit_rate() > 0.5,
                    "{name}: latest reads mostly missing ({:.2})",
                    r.read_hit_rate()
                );
            }
        }
    }
}
