//! Key-value separation conformance: the WiscKey-style value log must
//! be invisible when disabled, transparent when enabled, and crash-safe
//! always.
//!
//! - Off means OFF: a store whose vlog never triggers (threshold above
//!   every value) is op-for-op bit-identical to a store built with
//!   separation disabled, on every engine kind — the same invariant
//!   that keeps `vlog_threshold: 0` identical to the pre-vlog tree.
//! - Pointer dereference is transparent: the same workload run with and
//!   without separation reads back identical values (the read boundary
//!   normalizes separated descriptors to inline).
//! - Crash points straddling vlog appends and GC relocations recover
//!   prefix-consistently: an acked-and-barriered write is never lost,
//!   a lost tail never resurrects a never-acked value.
//! - A snapshot pins the pre-GC view: GC may retire a pinned segment's
//!   log space, but the snapshot still reads the old copies.

use std::collections::HashMap;

use kvaccel::baselines::SystemKind;
use kvaccel::engine::{EngineBuilder, EngineStats, IterOptions, KvEngine};
use kvaccel::env::SimEnv;
use kvaccel::kvaccel::RollbackScheme;
use kvaccel::lsm::{Key, LsmOptions, ValueDesc, ValueLoc};
use kvaccel::ssd::SsdConfig;
use kvaccel::workload::{self, BenchConfig, ClientConfig, WorkloadSpec};

const ENGINE_KINDS: [SystemKind; 6] = [
    SystemKind::RocksDb { slowdown: true },
    SystemKind::RocksDb { slowdown: false },
    SystemKind::Adoc,
    SystemKind::Kvaccel { scheme: RollbackScheme::Eager },
    SystemKind::Kvaccel { scheme: RollbackScheme::Lazy },
    SystemKind::Kvaccel { scheme: RollbackScheme::Disabled },
];

/// Big enough to separate (>= the 1 KiB test threshold).
fn v(tag: u32) -> ValueDesc {
    ValueDesc::new(tag, 4096)
}

/// Separation on: 1 KiB threshold, tiny segments so a test-sized run
/// seals many and GC gets real victims.
fn vlog_opts() -> LsmOptions {
    LsmOptions::small_for_test()
        .with_vlog_threshold(1024)
        .with_vlog_segment_bytes(16 << 10)
}

fn build(opts: LsmOptions, kind: SystemKind, seed: u64) -> (Box<dyn KvEngine>, SimEnv) {
    (
        EngineBuilder::new(kind).opts(opts).build(),
        SimEnv::new(seed, SsdConfig::default()),
    )
}

#[test]
fn untriggered_vlog_is_bit_identical_to_disabled() {
    // threshold u32::MAX: the feature is "on" but no value ever reaches
    // it, so no vlog is ever created and every op must trace exactly as
    // a disabled store — the only code gate is `separate_value`, which
    // is also why threshold 0 matches the pre-vlog tree bit-for-bit.
    let cfg = BenchConfig {
        duration: 2_000_000_000,
        key_space: 4096,
        ..Default::default()
    };
    let spec = WorkloadSpec::from_bench("A/fillrandom", &cfg)
        .with_clients(vec![ClientConfig::writer(), ClientConfig::reader()]);
    for kind in ENGINE_KINDS {
        let (mut off, mut env_a) =
            build(LsmOptions::small_for_test(), kind, 7);
        let (ra, trace_a) = workload::run_spec_traced(&mut *off, &mut env_a, &spec, true);

        let opts_on = LsmOptions::small_for_test().with_vlog_threshold(u32::MAX);
        let (mut on, mut env_b) = build(opts_on, kind, 7);
        let (rb, trace_b) = workload::run_spec_traced(&mut *on, &mut env_b, &spec, true);

        assert_eq!(
            trace_a,
            trace_b,
            "{}: untriggered vlog diverged from disabled",
            kind.label()
        );
        assert_eq!(ra.writes.total, rb.writes.total, "{}", kind.label());
        assert_eq!(ra.write_lat.p99_us, rb.write_lat.p99_us, "{}", kind.label());
        assert_eq!(ra.read_lat.p99_us, rb.read_lat.p99_us, "{}", kind.label());
        assert_eq!(ra.stopped_s, rb.stopped_s, "{}", kind.label());
        let vs = on.main_db().vlog_stats();
        assert_eq!(vs.appends, 0, "{}: nothing may separate", kind.label());
        assert_eq!(off.main_db().vlog_total_bytes(), 0, "{}", kind.label());
        assert_eq!(on.main_db().vlog_total_bytes(), 0, "{}", kind.label());
    }
}

#[test]
fn pointer_dereference_matches_the_inline_oracle() {
    // identical write sequences into a separated and an inline store:
    // every point read must return the same value descriptor (location
    // is normalized away at the read boundary), even after flushes,
    // compactions and GC have moved the separated copies around.
    for kind in ENGINE_KINDS {
        let (mut sep, mut env_s) = build(vlog_opts(), kind, 11);
        let (mut inl, mut env_i) = build(LsmOptions::small_for_test(), kind, 11);
        let mut ts = 0;
        let mut ti = 0;
        for i in 0..3000u32 {
            let k = (i * 37) % 509;
            if i % 23 == 5 {
                ts = sep.delete(&mut env_s, ts, k).done;
                ti = inl.delete(&mut env_i, ti, k).done;
            } else {
                ts = sep.put(&mut env_s, ts, k, v(i)).done;
                ti = inl.put(&mut env_i, ti, k, v(i)).done;
            }
        }
        ts = sep.flush(&mut env_s, ts);
        ti = inl.flush(&mut env_i, ti);
        let vs = sep.main_db().vlog_stats();
        assert!(vs.appends > 0, "{}: separation never engaged", kind.label());
        for k in 0..509u32 {
            let (got_s, nts) = sep.get(&mut env_s, ts, k);
            ts = nts;
            let (got_i, nti) = inl.get(&mut env_i, ti, k);
            ti = nti;
            assert_eq!(
                got_s,
                got_i,
                "{}: key {k} reads differently through the vlog",
                kind.label()
            );
            if let Some(d) = got_s {
                assert_eq!(
                    d.loc,
                    ValueLoc::Inline,
                    "{}: read boundary leaked a vlog pointer",
                    kind.label()
                );
            }
        }
    }
}

/// Per-key acked history + barrier cut (the recovery_conformance
/// oracle, reused for the separated write path).
#[derive(Default)]
struct Oracle {
    history: HashMap<Key, Vec<Option<ValueDesc>>>,
    barrier: HashMap<Key, usize>,
}

impl Oracle {
    fn record(&mut self, key: Key, val: Option<ValueDesc>) {
        self.history.entry(key).or_default().push(val);
    }

    fn set_barrier(&mut self) {
        for (k, h) in &self.history {
            self.barrier.insert(*k, h.len() - 1);
        }
    }

    fn check(&self, key: Key, got: Option<ValueDesc>, label: &str) {
        let Some(h) = self.history.get(&key) else {
            assert_eq!(got, None, "{label}: key {key} never written");
            return;
        };
        let allowed: Vec<Option<ValueDesc>> = match self.barrier.get(&key) {
            Some(&b) => h[b..].to_vec(),
            None => {
                let mut a = h.clone();
                a.push(None);
                a
            }
        };
        assert!(
            allowed.contains(&got),
            "{label}: key {key} recovered {got:?}, allowed {allowed:?}"
        );
    }
}

#[test]
fn crash_points_straddling_appends_and_gc_recover_prefix_consistent() {
    // overwrite-heavy separated writes over a small key range: tiny
    // segments + rapid shadowing keep the GC busy, and the LCG-varied
    // run length lands the crash at arbitrary phases (mid-append tail,
    // just after a GC relocation, between the two syncs' edits landing)
    let mut x: u64 = 0xA5A5_5A5A_0F0F_F0F0;
    for kind in ENGINE_KINDS {
        for trial in 0..3u64 {
            x = x.wrapping_mul(6364136223846793005).wrapping_add(1442695040888963407);
            let n2 = 150 + (x % 1200) as u32;
            let (mut sys, mut env) = build(vlog_opts(), kind, 300 + trial);
            let mut oracle = Oracle::default();
            let mut t = 0;
            for i in 0..400u32 {
                let k = (i * 37) % 211;
                t = sys.put(&mut env, t, k, v(i)).done;
                oracle.record(k, Some(v(i)));
            }
            t = sys.flush(&mut env, t);
            oracle.set_barrier();
            for i in 0..n2 {
                let k = (i * 53) % 211;
                if i % 29 == 7 {
                    t = sys.delete(&mut env, t, k).done;
                    oracle.record(k, None);
                } else {
                    t = sys.put(&mut env, t, k, v(10_000 + i)).done;
                    oracle.record(k, Some(v(10_000 + i)));
                }
            }
            let vs = sys.main_db().vlog_stats();
            assert!(vs.appends > 0, "{}: vlog never engaged", kind.label());
            let image = sys.crash(&mut env, t);
            assert!(!image.clean);
            let (mut sys2, mut t2) =
                EngineBuilder::open(&mut env, t, image).expect("recovery failed");
            let label = format!("{} n2={n2}", kind.label());
            for key in 0..211u32 {
                let (got, nt) = sys2.get(&mut env, t2, key);
                t2 = nt;
                oracle.check(key, got, &label);
                if let Some(d) = got {
                    assert_eq!(
                        d.loc,
                        ValueLoc::Inline,
                        "{label}: recovered read leaked a pointer"
                    );
                }
            }
        }
    }
}

#[test]
fn gc_runs_on_every_engine_kind_under_a_plain_write_load() {
    // the write-path piggyback: no external tick driver, just puts —
    // dead-space from overwrites must still get collected everywhere
    for kind in ENGINE_KINDS {
        let (mut sys, mut env) = build(vlog_opts(), kind, 5);
        let mut t = 0;
        for round in 0..40u32 {
            for k in 0..64u32 {
                t = sys.put(&mut env, t, k, v(round * 64 + k)).done;
            }
        }
        let vs = sys.main_db().vlog_stats();
        assert!(
            vs.gc_runs > 0,
            "{}: GC never ran under a pure put load (got {:?})",
            kind.label(),
            vs
        );
        assert!(
            vs.gc_reclaimed_bytes > 0,
            "{}: GC ran but reclaimed nothing",
            kind.label()
        );
        // GC keeps residual dead space bounded: strictly less than the
        // whole log (the trigger fires at the 0.4 dead ratio)
        let total = sys.main_db().vlog_total_bytes();
        let dead = sys.main_db().vlog_dead_bytes();
        assert!(
            total == 0 || dead < total,
            "{}: dead bytes {} not bounded by log size {}",
            kind.label(),
            dead,
            total
        );
    }
}

#[test]
fn snapshot_pins_the_pre_gc_view_while_gc_rewrites_it() {
    let (mut sys, mut env) = build(vlog_opts(), SystemKind::RocksDb { slowdown: true }, 13);
    let mut t = 0;
    // seed generation: one separated value per key
    for k in 0..64u32 {
        t = sys.put(&mut env, t, k, v(k)).done;
    }
    let snap = sys.snapshot(&mut env, t);
    // churn: shadow every seeded value many times over, which marks the
    // old segments dead and drives GC while the snapshot still pins them
    for round in 1..40u32 {
        for k in 0..64u32 {
            t = sys.put(&mut env, t, k, v(round * 1000 + k)).done;
        }
    }
    let vs = sys.main_db().vlog_stats();
    assert!(vs.gc_runs > 0, "churn never triggered GC: {vs:?}");
    // the snapshot still reads every pre-churn value, GC or not
    let mut it = sys.iter(&mut env, t, IterOptions::new().at(&snap));
    let mut t2 = it.seek_to_first(&mut env, t);
    let mut seen = 0u32;
    while it.valid() {
        let e = it.entry().unwrap();
        assert_eq!(
            e.val,
            v(e.key),
            "snapshot read key {} post-GC: got {:?}",
            e.key,
            e.val
        );
        seen += 1;
        t2 = it.next(&mut env, t2);
    }
    drop(it);
    assert_eq!(seen, 64, "snapshot scan lost keys under GC churn");
    // the live view meanwhile reads the newest generation
    let (got, _) = sys.get(&mut env, t2, 7);
    assert_eq!(got, Some(v(39 * 1000 + 7)));
}
