//! Smoke test: every registered experiment runs end-to-end at a tiny
//! scale and emits its CSV rows (the figure/table reproduction machinery
//! itself is exercised in CI).

use kvaccel::experiments::{run, EngineMode, ExpContext, ALL_EXPERIMENTS};

#[test]
fn all_experiments_run_at_tiny_scale() {
    let mut ctx = ExpContext::new(0.01, 7, EngineMode::Rust).unwrap();
    ctx.out_dir = std::path::PathBuf::from(std::env::temp_dir())
        .join("kvaccel_exp_smoke");
    ctx.quiet = true;
    for id in ALL_EXPERIMENTS {
        let summary = run(&ctx, id).unwrap_or_else(|e| panic!("{id}: {e:#}"));
        assert!(summary.contains("=="), "{id} produced no summary");
    }
    // spot-check a CSV landed
    assert!(ctx.out_dir.join("fig12.csv").exists());
}

#[test]
fn unknown_experiment_errors() {
    let ctx = ExpContext::new(0.01, 7, EngineMode::Rust).unwrap();
    assert!(run(&ctx, "fig99").is_err());
}
