//! Recovery conformance: for any injected crash point, reopening an
//! engine must yield a prefix-consistent view of the acked writes —
//! nothing durable lost (flushed SSTs, synced WAL records, the
//! capacitor-backed device buffer), nothing resurrected over a newer
//! durable version, no torn KVACCEL redirection — and a clean close must
//! reopen with zero WAL records to replay.
//!
//! Oracle: every write is recorded with a global index. An explicit
//! `flush()` barrier makes everything before it durable, so for each key
//! the recovered value must be one of the acked versions at or after the
//! key's barrier version (sync=false may lose the page-cached tail, but
//! never a barrier-covered write, and never yield a value that was never
//! acked).

use std::collections::HashMap;

use kvaccel::baselines::SystemKind;
use kvaccel::engine::{EngineBuilder, EngineStats, IterOptions, KvEngine};
use kvaccel::env::SimEnv;
use kvaccel::kvaccel::{KvaccelConfig, KvaccelDb, RollbackScheme};
use kvaccel::lsm::{Key, LsmOptions, ValueDesc};
use kvaccel::runtime::{BloomBuilder, MergeEngine};
use kvaccel::sim::{Nanos, MILLIS};
use kvaccel::ssd::SsdConfig;

const ENGINE_KINDS: [SystemKind; 6] = [
    SystemKind::RocksDb { slowdown: true },
    SystemKind::RocksDb { slowdown: false },
    SystemKind::Adoc,
    SystemKind::Kvaccel { scheme: RollbackScheme::Eager },
    SystemKind::Kvaccel { scheme: RollbackScheme::Lazy },
    SystemKind::Kvaccel { scheme: RollbackScheme::Disabled },
];

fn build(kind: SystemKind, seed: u64) -> (Box<dyn KvEngine>, SimEnv) {
    (
        EngineBuilder::new(kind)
            .opts(LsmOptions::small_for_test())
            .build(),
        SimEnv::new(seed, SsdConfig::default()),
    )
}

fn v(tag: u32) -> ValueDesc {
    ValueDesc::new(tag, 4096)
}

/// Per-key acked history + the barrier cut, driving the oracle.
#[derive(Default)]
struct Oracle {
    /// Acked versions per key in write order (None = tombstone).
    history: HashMap<Key, Vec<Option<ValueDesc>>>,
    /// Index into `history[k]` of the last version covered by a flush
    /// barrier (everything at or before it is durable).
    barrier: HashMap<Key, usize>,
}

impl Oracle {
    fn record(&mut self, key: Key, val: Option<ValueDesc>) {
        self.history.entry(key).or_default().push(val);
    }

    fn set_barrier(&mut self) {
        for (k, h) in &self.history {
            self.barrier.insert(*k, h.len() - 1);
        }
    }

    /// Prefix-consistency check for one recovered read.
    fn check(&self, key: Key, got: Option<ValueDesc>, label: &str) {
        let Some(h) = self.history.get(&key) else {
            assert_eq!(got, None, "{label}: key {key} never written");
            return;
        };
        let from = self.barrier.get(&key).copied();
        let allowed: Vec<Option<ValueDesc>> = match from {
            Some(b) => h[b..].to_vec(),
            // no barrier-covered version: post-barrier writes may all be
            // lost, so absence is allowed too
            None => {
                let mut a = h.clone();
                a.push(None);
                a
            }
        };
        assert!(
            allowed.contains(&got),
            "{label}: key {key} recovered {got:?}, allowed {allowed:?}"
        );
    }
}

/// Write `n1` keys, flush-barrier, write `n2` more (overwrites + a few
/// deletes), then crash. Returns (engine-less) env, oracle, crash time.
fn run_workload(
    sys: &mut dyn KvEngine,
    env: &mut SimEnv,
    oracle: &mut Oracle,
    n1: u32,
    n2: u32,
) -> Nanos {
    let mut t = 0;
    for i in 0..n1 {
        let k = (i * 37) % 701;
        t = sys.put(env, t, k, v(i)).done;
        oracle.record(k, Some(v(i)));
    }
    t = sys.flush(env, t);
    oracle.set_barrier();
    for i in 0..n2 {
        let k = (i * 53) % 701;
        if i % 29 == 7 {
            t = sys.delete(env, t, k).done;
            oracle.record(k, None);
        } else {
            t = sys.put(env, t, k, v(10_000 + i)).done;
            oracle.record(k, Some(v(10_000 + i)));
        }
    }
    t
}

#[test]
fn clean_close_reopens_with_zero_wal_records() {
    for kind in ENGINE_KINDS {
        let (mut sys, mut env) = build(kind, 21);
        let mut oracle = Oracle::default();
        let t = run_workload(&mut *sys, &mut env, &mut oracle, 400, 300);
        let image = sys.close(&mut env, t).unwrap();
        assert!(image.clean, "{}: close must mark the image clean", kind.label());
        assert_eq!(
            image.wal_records(),
            0,
            "{}: clean close must seal + drain the WAL",
            kind.label()
        );
        let (mut sys2, mut t2) = EngineBuilder::open(&mut env, t, image).expect("recovery failed");
        let h = sys2.health();
        assert_eq!(
            h.recovered_wal_records,
            0,
            "{}: clean reopen must replay zero records",
            kind.label()
        );
        assert_eq!(h.recoveries, 1);
        // after a clean close every acked write is durable: exact check
        for key in 0..701u32 {
            let want = oracle
                .history
                .get(&key)
                .and_then(|h| h.last().copied())
                .flatten();
            let (got, nt) = sys2.get(&mut env, t2, key);
            t2 = nt;
            assert_eq!(got, want, "{}: key {key} after clean reopen", kind.label());
        }
    }
}

#[test]
fn crash_recovery_is_prefix_consistent_across_engines() {
    // deterministic pseudo-random crash points per engine kind
    let mut x: u64 = 0x9E37_79B9;
    for kind in ENGINE_KINDS {
        for trial in 0..3u64 {
            x = x.wrapping_mul(6364136223846793005).wrapping_add(1442695040888963407);
            let n2 = 120 + (x % 1400) as u32;
            let (mut sys, mut env) = build(kind, 100 + trial);
            let mut oracle = Oracle::default();
            let t = run_workload(&mut *sys, &mut env, &mut oracle, 500, n2);
            let image = sys.crash(&mut env, t);
            assert!(!image.clean);
            let (mut sys2, mut t2) = EngineBuilder::open(&mut env, t, image).expect("recovery failed");
            let label = format!("{} n2={n2}", kind.label());
            for key in 0..701u32 {
                let (got, nt) = sys2.get(&mut env, t2, key);
                t2 = nt;
                oracle.check(key, got, &label);
            }
        }
    }
}

#[test]
fn double_crash_stays_prefix_consistent() {
    // crash, recover, keep writing, crash again: the second life's WAL
    // watermark must not inherit the first life's byte count (a reopened
    // log starts a fresh stream), so the second recovery is still
    // prefix-consistent
    for kind in [
        SystemKind::RocksDb { slowdown: true },
        SystemKind::Kvaccel { scheme: RollbackScheme::Disabled },
    ] {
        let (mut sys, mut env) = build(kind, 33);
        let mut oracle = Oracle::default();
        let t = run_workload(&mut *sys, &mut env, &mut oracle, 400, 350);
        let image = sys.crash(&mut env, t);
        let (mut sys2, t2) = EngineBuilder::open(&mut env, t, image).expect("recovery failed");
        // second life: a short burst with NO barrier, then crash again
        let mut t3 = t2;
        for i in 0..40u32 {
            let k = (i * 11) % 701;
            t3 = sys2.put(&mut env, t3, k, v(20_000 + i)).done;
            oracle.record(k, Some(v(20_000 + i)));
        }
        let image2 = sys2.crash(&mut env, t3);
        // the fresh-stream invariant: the second-life burst (~165 KB,
        // far under the 1 MB page cache) must NOT read as durable just
        // because the first life wrote megabytes to the old log
        let new_durable = image2
            .wal
            .iter()
            .filter(|e| !e.val.is_tombstone() && e.val.seed >= 20_000)
            .count();
        assert_eq!(
            new_durable,
            0,
            "{}: second-life page-cached tail leaked into the durable cut",
            kind.label()
        );
        let (mut sys3, mut t4) = EngineBuilder::open(&mut env, t3, image2).expect("recovery failed");
        let label = format!("{} double-crash", kind.label());
        for key in 0..701u32 {
            let (got, nt) = sys3.get(&mut env, t4, key);
            t4 = nt;
            oracle.check(key, got, &label);
        }
    }
}

#[test]
fn snapshot_and_iterator_conform_on_a_reopened_engine() {
    for kind in ENGINE_KINDS {
        let (mut sys, mut env) = build(kind, 77);
        let mut oracle = Oracle::default();
        let t = run_workload(&mut *sys, &mut env, &mut oracle, 600, 500);
        let image = sys.crash(&mut env, t);
        let (mut sys2, t2) = EngineBuilder::open(&mut env, t, image).expect("recovery failed");
        // cursor over the full range: keys strictly ascending, every
        // scanned entry agrees with a point get, every entry passes the
        // prefix-consistency oracle
        let snap = sys2.snapshot(&mut env, t2);
        let mut it = sys2.iter(&mut env, t2, IterOptions::new().at(&snap));
        let mut t3 = it.seek_to_first(&mut env, t2);
        let mut last: Option<Key> = None;
        let mut scanned: Vec<(Key, ValueDesc)> = Vec::new();
        while it.valid() {
            let e = it.entry().unwrap();
            if let Some(l) = last {
                assert!(e.key > l, "{}: unsorted cursor", kind.label());
            }
            last = Some(e.key);
            scanned.push((e.key, e.val));
            t3 = it.next(&mut env, t3);
        }
        drop(it);
        let label = format!("{} reopened-scan", kind.label());
        for &(k, val) in &scanned {
            oracle.check(k, Some(val), &label);
            let (got, nt) = sys2.get(&mut env, t3, k);
            t3 = nt;
            assert_eq!(
                got,
                Some(val),
                "{}: scan/get divergence at key {k}",
                kind.label()
            );
        }
        assert!(!scanned.is_empty(), "{}: empty store after reopen", kind.label());
    }
}

#[test]
fn unsynced_tail_is_lost_but_barrier_writes_survive() {
    // the sync=false ack-vs-durable gap, isolated: a handful of writes
    // that fit the page cache vanish at power loss; after a flush
    // barrier they survive
    let (mut sys, mut env) = build(SystemKind::RocksDb { slowdown: true }, 5);
    let mut t = 0;
    for k in 0..5u32 {
        t = sys.put(&mut env, t, k, v(k)).done;
    }
    let image = sys.crash(&mut env, t);
    assert_eq!(image.wal_records(), 0, "nothing synced, nothing durable");
    let (mut sys2, t2) = EngineBuilder::open(&mut env, t, image).expect("recovery failed");
    let (got, _) = sys2.get(&mut env, t2, 3);
    assert_eq!(got, None, "page-cached write must not survive a crash");

    let (mut sys, mut env) = build(SystemKind::RocksDb { slowdown: true }, 5);
    let mut t = 0;
    for k in 0..5u32 {
        t = sys.put(&mut env, t, k, v(k)).done;
    }
    t = sys.flush(&mut env, t);
    let image = sys.crash(&mut env, t);
    let (mut sys2, mut t2) = EngineBuilder::open(&mut env, t, image).expect("recovery failed");
    for k in 0..5u32 {
        let (got, nt) = sys2.get(&mut env, t2, k);
        t2 = nt;
        assert_eq!(got, Some(v(k)), "barrier-covered key {k} lost");
    }
}

#[test]
fn kvaccel_redirected_writes_survive_any_crash() {
    // redirected writes land in the capacitor-backed device buffer and
    // are durable at ack — even when every page-cached main-path write
    // of the same run is lost
    let (mut db, mut env) = kv_rig(RollbackScheme::Disabled);
    let mut t = 0;
    for k in 0..4000u32 {
        t = db.put(&mut env, t, k, v(k)).done;
    }
    assert!(
        db.controller.stats.writes_to_dev > 0,
        "pressure should have redirected writes"
    );
    let routed = db.metadata.pin();
    assert!(!routed.is_empty());
    let mut routed_keys: Vec<Key> = routed.iter().copied().collect();
    routed_keys.sort_unstable();
    let image = db.crash_into_image(&mut env, t);
    let (mut db2, mut t2) = open_kv(&mut env, t, image);
    assert!(db2.main.recovery.dev_entries_scanned > 0);
    for k in routed_keys {
        let (got, nt) = db2.get(&mut env, t2, k);
        t2 = nt;
        assert_eq!(got, Some(v(k)), "redirected key {k} lost at crash");
    }
}

#[test]
fn kvaccel_crash_mid_rollback_reconciles_routing() {
    let (mut db, mut env) = kv_rig(RollbackScheme::Eager);
    let mut t = 0;
    // pressure phase: force redirection into the device buffer
    for k in 0..4000u32 {
        t = db.put(&mut env, t, k, v(k)).done;
    }
    assert!(
        db.controller.stats.writes_to_dev > 0,
        "pressure should have redirected writes"
    );
    // barrier: make every main-path write durable so the only state the
    // crash can tear is the rollback window itself
    t = kvaccel::engine::KvEngine::flush(&mut db, &mut env, t);
    // calm phase: spaced reads tick the detector until an eager rollback
    // window opens
    let mut window: Option<(Nanos, Nanos)> = None;
    for _ in 0..400 {
        t += 100 * MILLIS;
        let (_, nt) = db.get(&mut env, t, 1);
        t = nt;
        if let Some(end) = db.rollback.pending_end() {
            if end > t + 1 {
                window = Some((t, end));
                break;
            }
        }
    }
    let (now, end) = window.expect("eager rollback never opened a window");
    // crash strictly inside the window: merge-back ran, reset did not
    let crash_at = now + (end - now) / 2;
    assert!(db.rollback.in_flight(crash_at));
    let image = db.crash_into_image(&mut env, crash_at);
    let (mut db2, mut t2) = open_kv(&mut env, crash_at, image);
    assert_eq!(
        db2.main.recovery.interrupted_rollbacks, 1,
        "dangling RollbackBegin must be detected"
    );
    // no torn redirection: every acked key must read one of its acked
    // values; keys the reconciliation routed to the device must resolve
    // to their device copy
    for k in (0..4000u32).step_by(7) {
        let (got, nt) = db2.get(&mut env, t2, k);
        t2 = nt;
        assert_eq!(got, Some(v(k)), "key {k} torn by mid-rollback crash");
    }
    assert_eq!(
        db2.metadata.len() as u64,
        db2.main.recovery.dev_keys_rerouted,
        "routing set must match the reconciliation verdict"
    );
}

// ---------------------------------------------------------------------
// helpers for the concrete-KVACCEL tests
// ---------------------------------------------------------------------

fn kv_rig(scheme: RollbackScheme) -> (KvaccelDb, SimEnv) {
    (
        KvaccelDb::new(
            LsmOptions::small_for_test(),
            KvaccelConfig::default().with_scheme(scheme),
            MergeEngine::rust(),
            BloomBuilder::rust(),
        ),
        SimEnv::new(9, SsdConfig::default()),
    )
}

fn open_kv(
    env: &mut SimEnv,
    at: Nanos,
    image: kvaccel::engine::DurableImage,
) -> (KvaccelDb, Nanos) {
    let cfg = image.kvaccel_cfg.expect("kvaccel image carries its config");
    KvaccelDb::open(
        env,
        at,
        image.opts,
        cfg,
        image.merge,
        image.bloom,
        image.manifest,
        image.wal,
        image.vlog,
        image.clean,
    )
    .expect("recovery failed")
}
