//! Integration: the python-AOT -> rust-PJRT bridge.
//!
//! Loads the real artifacts produced by `make artifacts`, executes the
//! merge and bloom graphs through PJRT, and checks bit-identity against
//! the pure-Rust references. Skips (with a loud message) if artifacts are
//! missing.

use kvaccel::runtime::bloom::build_bitmap_rust;
use kvaccel::runtime::merge::merge_window_rust;
use kvaccel::runtime::{default_artifacts_dir, BloomBuilder, MergeEngine, XlaRuntime};
use kvaccel::sim::SimRng;
use std::sync::Arc;

fn runtime() -> Option<Arc<XlaRuntime>> {
    match XlaRuntime::load(default_artifacts_dir()) {
        Ok(rt) => Some(Arc::new(rt)),
        Err(e) => {
            eprintln!("SKIP runtime tests (run `make artifacts`): {e:#}");
            None
        }
    }
}

// One #[test] driving every check: the PJRT client/executables are not
// Sync (xla crate uses Rc), so we load + compile the artifact set once
// and run all verifications sequentially on this thread.
#[test]
fn roundtrip_suite() {
    let Some(rt) = runtime() else { return };
    merge_artifact_matches_rust_reference(rt.clone());
    merge_artifact_dedups_newest_first(rt.clone());
    merge_artifact_empty_and_pad_handling(rt.clone());
    bloom_artifact_matches_rust_reference(rt.clone());
    runtime_reports_shapes(rt);
}

fn merge_artifact_matches_rust_reference(rt: Arc<XlaRuntime>) {
    let engine = MergeEngine::xla(rt).unwrap();
    let mut rng = SimRng::new(42);
    for n in [1usize, 7, 100, 1024, 4096, 5000, 20_000] {
        let pairs: Vec<(u32, u32)> = (0..n)
            .map(|i| (rng.next_u32() % 10_000, i as u32))
            .collect();
        let got = engine.merge_window(&pairs).unwrap();
        let want = merge_window_rust(&pairs);
        assert_eq!(got, want, "mismatch at n={n}");
    }
}

fn merge_artifact_dedups_newest_first(rt: Arc<XlaRuntime>) {
    let engine = MergeEngine::xla(rt).unwrap();
    // key 5 appears with tags 3, 9, 17 -> tag 3 (newest) must win
    let pairs = vec![(5u32, 9u32), (1, 0), (5, 3), (2, 1), (5, 17)];
    let got = engine.merge_window(&pairs).unwrap();
    assert_eq!(got, vec![(1, 0), (2, 1), (5, 3)]);
}

fn merge_artifact_empty_and_pad_handling(rt: Arc<XlaRuntime>) {
    let engine = MergeEngine::xla(rt).unwrap();
    assert!(engine.merge_window(&[]).unwrap().is_empty());
    // a window that forces padding (size not matching any artifact)
    let pairs: Vec<(u32, u32)> = (0..37).map(|i| (1000 - i, i)).collect();
    let got = engine.merge_window(&pairs).unwrap();
    assert_eq!(got.len(), 37);
    assert!(got.windows(2).all(|w| w[0].0 < w[1].0));
}

fn bloom_artifact_matches_rust_reference(rt: Arc<XlaRuntime>) {
    let builder = BloomBuilder::xla(rt.clone());
    let shapes = rt.bloom_shapes();
    assert!(!shapes.is_empty(), "no bloom artifacts");
    for &(n, p, m) in &shapes {
        let mut rng = SimRng::new(n as u64);
        // partially-filled batch exercises the padding-drop path
        let keys: Vec<u32> = (0..n / 2 + 1).map(|_| rng.next_u32() / 2).collect();
        let got = builder.build(&keys, p, m as u32).unwrap();
        let want = build_bitmap_rust(&keys, p, m as u32);
        assert_eq!(got, want, "bloom mismatch at shape ({n},{p},{m})");
    }
}

fn runtime_reports_shapes(rt: Arc<XlaRuntime>) {
    let shapes = rt.merge_shapes();
    assert!(shapes.contains(&(1, 4096)), "expected merge_b1_n4096: {shapes:?}");
    assert!(shapes.iter().all(|&(b, n)| b >= 1 && n.is_power_of_two()));
}
