//! Quickstart: open a KVACCEL store, write/read/scan, survive a rollback.
//!
//!     cargo run --release --example quickstart

use kvaccel::env::SimEnv;
use kvaccel::kvaccel::{KvaccelConfig, KvaccelDb, RollbackScheme};
use kvaccel::lsm::{LsmOptions, ValueDesc};
use kvaccel::runtime::{BloomBuilder, MergeEngine};
use kvaccel::ssd::SsdConfig;

fn main() -> anyhow::Result<()> {
    // A KVACCEL store = Main-LSM on the block interface + Dev-LSM write
    // buffer on the KV interface of one simulated dual-interface SSD.
    let mut db = KvaccelDb::new(
        LsmOptions::default(),
        KvaccelConfig::default().with_scheme(RollbackScheme::Eager),
        MergeEngine::rust(), // see e2e_validation for the XLA engine
        BloomBuilder::rust(),
    );
    let mut env = SimEnv::new(7, SsdConfig::default());

    // write 50k pairs (4 B keys / 4 KB values, the paper's config)
    let mut t = 0;
    for k in 0..50_000u32 {
        t = db.put(&mut env, t, k, ValueDesc::new(k, 4096)).done;
    }
    println!("wrote 50k pairs in {:.3} virtual s", t as f64 / 1e9);
    println!(
        "redirected to Dev-LSM: {} puts ({:.1}%)",
        db.controller.stats.writes_to_dev,
        db.controller.redirect_fraction() * 100.0
    );

    // point reads route by metadata (Main vs Dev)
    let (v, t2) = db.get(&mut env, t, 12_345);
    println!("get(12345) = {v:?} at t={:.3}s", t2 as f64 / 1e9);
    assert_eq!(v, Some(ValueDesc::new(12_345, 4096)));

    // range scan across BOTH interfaces (dual-iterator aggregation)
    let (entries, t3) = db.scan(&mut env, t2, 100, 10);
    println!(
        "scan(100..) -> {:?}",
        entries.iter().map(|e| e.key).collect::<Vec<_>>()
    );

    // finish: rollback any buffered pairs into the Main-LSM
    let t4 = db.finish(&mut env, t3)?;
    println!(
        "finished at {:.3}s: {} rollbacks returned {} pairs",
        t4 as f64 / 1e9,
        db.rollback.stats.rollbacks,
        db.rollback.stats.entries_returned
    );
    assert!(env.device.kv_is_empty(db.namespace()));
    println!("quickstart OK");
    Ok(())
}
